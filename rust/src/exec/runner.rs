//! The executor front door: [`ExecConfig`] (how many workers, how to
//! shard, how to ingest) and [`ShardedRunner`] (materialized: plan →
//! pool → merge; streaming: ingest → steal → ordered emit).

use std::cell::RefCell;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use super::factory::{PipelineFactory, Splittability};
use super::fault::FaultPolicy;
use super::ingest::IngestPolicy;
use super::merge::{merge_results, ExecReport, RegionFolder, ReportBuilder};
use super::plan::{ShardPlan, ShardPolicy};
use super::pool::{ShardResult, WorkerPool, DEFAULT_WATCHDOG};
use super::split::{SharedSplitQueue, SplitQueue, SplitSource};
use super::steal::ClaimMode;
use crate::metrics::{LaneMetrics, MetricsReport, MetricsSpec};
use crate::trace::{Trace, TraceOptions, TraceSpec, WorkerTrace};
use crate::workload::source::RegionSource;

/// Executor configuration.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// Worker threads (pipeline replicas). Must be ≥ 1 — validated by
    /// the runner with a named error, never silently clamped.
    pub workers: usize,
    /// Shard-planning policy (materialized runs).
    pub shard: ShardPolicy,
    /// Streaming-ingest policy ([`ShardedRunner::run_stream`]).
    pub ingest: IngestPolicy,
    /// How workers claim shards (default: work stealing).
    pub claim: ClaimMode,
    /// Event tracing: `None` (the default) disables it completely —
    /// workers run the exact untraced hot path. `Some` records
    /// firing/shard/ingest/merge events into per-worker ring buffers and
    /// attaches the folded [`Trace`] to the report.
    pub trace: Option<TraceOptions>,
    /// Live telemetry: `false` (the default) disables it completely —
    /// every record site is one branch with no clock read. `true` meters
    /// the run (per-worker [`LaneMetrics`](crate::metrics::LaneMetrics)
    /// hubs, exact-folded) and attaches a
    /// [`MetricsReport`](crate::metrics::MetricsReport) to the report.
    /// Metering never changes scheduling: outputs are bit-identical
    /// either way.
    pub metrics: bool,
    /// Progress heartbeat period for streaming runs: `Some(every)`
    /// prints one machine-parseable `progress ...` line per interval
    /// from the ingest driver's own loop (no extra thread). Implies
    /// metrics (the heartbeat reads the live quantiles). `None` (the
    /// default) stays silent; materialized runs never tick.
    pub progress: Option<Duration>,
    /// What happens when a shard panics or errors (default:
    /// [`FaultPolicy::FailFast`] — the whole run aborts). See
    /// [`super::fault`] for `Retry` / `Quarantine` semantics.
    pub fault: FaultPolicy,
    /// Watchdog deadline for the pool's blocking waits: a run that makes
    /// no progress anywhere for this long fails with a named stall
    /// diagnostic instead of hanging. Must exceed the longest legitimate
    /// shard (and source gap); must be nonzero.
    pub watchdog: Duration,
    /// Intra-region split threshold: regions heavier than this many
    /// items are cut into sub-shards that different workers execute
    /// concurrently, with partials re-folded deterministically so the
    /// output stays bit-identical (see [`super::split`]). `0` (the
    /// default) disables splitting — the planner never cuts a region.
    /// Nonzero with a factory whose
    /// [`Splittability`](super::factory::Splittability) is `Opaque`
    /// makes every run refuse with a named error, even when no region
    /// exceeds the threshold.
    pub max_region_items: usize,
}

impl ExecConfig {
    /// `workers` threads with the default (one shard per worker,
    /// work-stealing) policy.
    pub fn new(workers: usize) -> ExecConfig {
        ExecConfig {
            workers,
            shard: ShardPolicy::default(),
            ingest: IngestPolicy::default(),
            claim: ClaimMode::default(),
            trace: None,
            metrics: false,
            progress: None,
            fault: FaultPolicy::default(),
            watchdog: DEFAULT_WATCHDOG,
            max_region_items: 0,
        }
    }

    /// One worker per available CPU.
    pub fn auto() -> ExecConfig {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ExecConfig::new(workers)
    }

    /// Builder-style override of the shard policy.
    pub fn with_shards_per_worker(mut self, shards_per_worker: usize) -> ExecConfig {
        self.shard.shards_per_worker = shards_per_worker.max(1);
        self
    }

    /// Builder-style streaming budget: at most `buffer_regions` regions
    /// in flight between ingest and the ordered merge (backpressure
    /// beyond it). Shard granularity stays on auto unless
    /// [`IngestPolicy::shard_regions`] is set explicitly. A zero (or
    /// absurd) budget is **not** clamped here — [`ExecConfig::validate`]
    /// rejects it by name, exactly like `workers = 0`.
    pub fn streaming(mut self, buffer_regions: usize) -> ExecConfig {
        self.ingest.buffer_regions = buffer_regions;
        self
    }

    /// Builder-style claim-mode override.
    pub fn with_claim(mut self, claim: ClaimMode) -> ExecConfig {
        self.claim = claim;
        self
    }

    /// Builder-style tracing override: `Some` enables event tracing for
    /// runs launched with this config (see [`crate::trace`]).
    pub fn with_trace(mut self, trace: Option<TraceOptions>) -> ExecConfig {
        self.trace = trace;
        self
    }

    /// Builder-style metrics toggle: `true` meters runs launched with
    /// this config (see [`crate::metrics`]); outputs stay bit-identical.
    pub fn with_metrics(mut self, metrics: bool) -> ExecConfig {
        self.metrics = metrics;
        self
    }

    /// Builder-style progress-heartbeat override: `Some(every)` prints
    /// one `progress ...` line per interval during streaming runs (and
    /// enables metrics, which the heartbeat reads). Zero is **not**
    /// clamped here — [`ExecConfig::validate`] rejects it by name.
    pub fn with_progress(mut self, every: Option<Duration>) -> ExecConfig {
        self.progress = every;
        self
    }

    /// Builder-style fault-policy override.
    pub fn with_fault(mut self, fault: FaultPolicy) -> ExecConfig {
        self.fault = fault;
        self
    }

    /// Builder-style watchdog-deadline override. Zero is **not** clamped
    /// here — [`ExecConfig::validate`] rejects it by name.
    pub fn with_watchdog(mut self, deadline: Duration) -> ExecConfig {
        self.watchdog = deadline;
        self
    }

    /// Builder-style intra-region split threshold: regions heavier than
    /// `max_items` are cut into sub-shards (`0` = never split, the
    /// default). Requires a factory that advertises a splittable
    /// [`Splittability`](super::factory::Splittability) — opaque stages
    /// refuse by name rather than reorder silently.
    pub fn with_max_region_items(mut self, max_items: usize) -> ExecConfig {
        self.max_region_items = max_items;
        self
    }

    /// Check the configuration, naming the offending field. The runner
    /// (and the apps' `run_sharded*`/`run_streaming*` fronts) call this
    /// up front so a zero-worker or zero-budget config fails loudly
    /// instead of being clamped.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.workers >= 1,
            "invalid exec config: workers = 0 (need at least one worker thread; \
             use ExecConfig::auto() for one per CPU)"
        );
        ensure!(
            self.ingest.buffer_regions >= 1,
            "invalid exec config: ingest buffer_regions = 0 (the streaming \
             budget must admit at least one region; pass --ingest-buffer >= 1)"
        );
        ensure!(
            self.ingest.buffer_regions <= MAX_INGEST_BUFFER,
            "invalid exec config: ingest buffer_regions = {} exceeds the sanity \
             cap {MAX_INGEST_BUFFER} (the budget is counted in regions, not bytes)",
            self.ingest.buffer_regions
        );
        if let FaultPolicy::Retry { max_attempts, .. } = self.fault {
            ensure!(
                max_attempts >= 1,
                "invalid exec config: fault policy retry max_attempts = 0 (a shard \
                 needs at least one attempt; pass --fault-retries >= 1)"
            );
        }
        ensure!(
            !self.watchdog.is_zero(),
            "invalid exec config: watchdog deadline = 0 (every blocking wait would \
             fail immediately; pass --watchdog-secs >= 1)"
        );
        if let Some(every) = self.progress {
            ensure!(
                !every.is_zero(),
                "invalid exec config: progress heartbeat period = 0 (the driver \
                 would print a line per loop iteration; pass --progress-secs >= 1)"
            );
        }
        Ok(())
    }
}

/// Sanity cap on [`IngestPolicy::buffer_regions`]: a budget past a
/// million *regions* in flight is almost certainly a unit mistake
/// (bytes or items passed where regions were meant). Sized by what the
/// budget actually pre-allocates: the stream merger's reassembly ring
/// holds one slot per in-flight region in the worst case (every shard a
/// single region), ~128 B each — ~130 MB at this cap, versus
/// out-of-memory territory for byte-sized mistakes. Enforced by
/// [`ExecConfig::validate`] and again by `WorkerPool::run_stream` for
/// direct pool callers.
pub const MAX_INGEST_BUFFER: usize = 1 << 20;

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig::new(1)
    }
}

/// Runs a [`PipelineFactory`]'s pipeline over a region stream, sharded
/// across workers, and merges the results deterministically.
#[derive(Debug, Clone)]
pub struct ShardedRunner {
    cfg: ExecConfig,
}

impl ShardedRunner {
    /// Create a runner over the given config.
    pub fn new(cfg: ExecConfig) -> ShardedRunner {
        ShardedRunner { cfg }
    }

    /// Shorthand for `ShardedRunner::new(ExecConfig::new(workers))`.
    pub fn with_workers(workers: usize) -> ShardedRunner {
        ShardedRunner::new(ExecConfig::new(workers))
    }

    /// The config this runner executes with.
    pub fn config(&self) -> &ExecConfig {
        &self.cfg
    }

    fn pool(&self) -> WorkerPool {
        // One shared epoch, stamped the moment the run is launched: both
        // trace events and metric latencies count nanoseconds from it, so
        // `trace summarize` latencies and the live MetricsReport are
        // directly comparable. Progress implies metrics (the heartbeat
        // reads the hub's live quantiles).
        let epoch = Instant::now();
        let metered = self.cfg.metrics || self.cfg.progress.is_some();
        WorkerPool::new(self.cfg.workers)
            .with_claim(self.cfg.claim)
            .with_trace(self.cfg.trace.map(|opts| {
                let mut spec = TraceSpec::from_options(opts);
                spec.epoch = epoch;
                spec
            }))
            .with_metrics(metered.then(|| MetricsSpec::with_epoch(epoch)))
            .with_progress(self.cfg.progress)
            .with_fault(self.cfg.fault)
            .with_watchdog(self.cfg.watchdog)
    }

    /// Attach the folded trace lanes to a finished report, pairing them
    /// with the node table (name, SIMD width) the metrics fold produced,
    /// so consumers can resolve `Firing { node }` ids to names.
    fn attach_trace<T>(report: &mut ExecReport<T>, traces: Vec<WorkerTrace>) {
        let nodes = report
            .metrics
            .nodes
            .iter()
            .map(|(name, m)| (name.clone(), m.width))
            .collect();
        report.trace = Some(Trace {
            workers: traces,
            nodes,
        });
    }

    /// Wrap a run's folded metrics lane into a
    /// [`MetricsReport`](crate::metrics::MetricsReport) on the finished
    /// report (no-op when the run was unmetered). Callers must have
    /// already stamped the salvage ledger and retired workers onto the
    /// report — the fault-domain counters are folded from it here.
    fn attach_metrics<T>(report: &mut ExecReport<T>, workers: usize, lanes: Option<LaneMetrics>) {
        if let Some(mut totals) = lanes {
            totals.partial_regions = report.partial_regions.len() as u64;
            totals.dead_workers = report.per_worker.iter().filter(|w| w.dead).count() as u64;
            report.metrics_report = Some(MetricsReport {
                workers,
                elapsed: report.elapsed,
                totals,
            });
        }
    }

    /// Plan shards at region boundaries, fan them out over the worker
    /// pool, and merge outputs back into stream order.
    ///
    /// `elapsed` on the report covers the claim/execute phase only:
    /// every worker's pipeline is prewarmed behind a barrier first, so
    /// graph construction never pollutes the measurement (shard planning
    /// is included — it is part of the work a run does).
    pub fn run<F: PipelineFactory>(
        &self,
        factory: &F,
        stream: &[F::In],
    ) -> Result<ExecReport<F::Out>> {
        self.cfg.validate()?;
        if self.cfg.max_region_items > 0 {
            return self.run_split(factory, stream);
        }
        let t0 = Instant::now();
        let weights: Vec<usize> = stream.iter().map(|r| factory.weight(r)).collect();
        let plan = ShardPlan::build(&weights, self.cfg.workers, &self.cfg.shard);
        let planning = t0.elapsed().as_secs_f64();
        let run = self.pool().run_collect(factory, stream, &plan)?;
        let mut report = merge_results(run.results, planning + run.elapsed);
        report.mark_retired(&run.retired);
        if self.cfg.trace.is_some() {
            Self::attach_trace(&mut report, run.traces);
        }
        Self::attach_metrics(&mut report, self.cfg.workers, run.metrics);
        Ok(report)
    }

    /// Refuse splitting up front (eagerly, even if no region would
    /// actually be cut) when the factory's state is not legally
    /// splittable — the refusal names the stage's reason.
    fn require_splittable<F: PipelineFactory>(factory: &F) -> Result<Splittability> {
        let split = factory.splittability();
        if let Splittability::Opaque { reason } = split {
            bail!(
                "region splitting refused: {reason} (this stage's region state is \
                 not an associative accumulator — run without --max-region-items, \
                 or pick a splittable mode)"
            );
        }
        Ok(split)
    }

    /// [`ShardedRunner::run`] with intra-region splitting: every region
    /// is cut into owned parts (oversized regions into several, the
    /// rest into a single clone), parts are planned and executed as
    /// first-class regions, and a [`RegionFolder`] re-folds each split
    /// region's rows in part order before the stream-order merge — so
    /// the report's outputs are bit-identical to the unsplit run's for
    /// [`Splittability::RegionFold`] factories.
    fn run_split<F: PipelineFactory>(
        &self,
        factory: &F,
        stream: &[F::In],
    ) -> Result<ExecReport<F::Out>> {
        let split = Self::require_splittable(factory)?;
        let record = split == Splittability::RegionFold;
        let max = self.cfg.max_region_items;
        let t0 = Instant::now();
        let mut queue = SplitQueue::new(record);
        let mut parts: Vec<F::In> = Vec::with_capacity(stream.len());
        for region in stream {
            let cut = factory.split_region(region, max)?;
            ensure!(
                !cut.is_empty(),
                "split_region returned no parts for region {}",
                queue.regions_seen()
            );
            queue.push_region(cut.len() as u32);
            parts.extend(cut);
        }
        let weights: Vec<usize> = parts.iter().map(|r| factory.weight(r)).collect();
        let plan = ShardPlan::build(&weights, self.cfg.workers, &self.cfg.shard);
        let planning = t0.elapsed().as_secs_f64();
        let run = self.pool().run_collect(factory, &parts, &plan)?;
        let split_regions = queue.regions_split();
        let mut results = run.results;
        let mut partials = Vec::new();
        if record {
            let mut folder = RegionFolder::new(Rc::new(RefCell::new(queue)));
            for r in &mut results {
                folder.fold_shard(factory, r)?;
            }
            folder.finish()?;
            partials = folder.take_partials();
        }
        let mut report = merge_results(results, planning + run.elapsed);
        report.split_regions = split_regions;
        report.partial_regions = partials;
        report.mark_retired(&run.retired);
        if self.cfg.trace.is_some() {
            Self::attach_trace(&mut report, run.traces);
        }
        Self::attach_metrics(&mut report, self.cfg.workers, run.metrics);
        Ok(report)
    }

    /// Streaming execution with collected outputs: regions are pulled
    /// from `source` incrementally (the calling thread is the ingest
    /// driver), sharded on the fly under the configured in-flight budget,
    /// executed with work stealing, and merged back into stream order —
    /// output-identical to [`ShardedRunner::run`] over the materialized
    /// stream. Input memory is bounded by the budget; outputs are still
    /// collected in full (use [`ShardedRunner::run_stream_with`] to
    /// consume them incrementally instead).
    pub fn run_stream<F, S>(&self, factory: &F, source: S) -> Result<ExecReport<F::Out>>
    where
        F: PipelineFactory,
        F::In: Send,
        S: RegionSource<Region = F::In>,
    {
        let mut outputs = Vec::new();
        let mut report = self.run_stream_with(factory, source, |mut r: ShardResult<F::Out>| {
            outputs.append(&mut r.outputs);
            Ok(())
        })?;
        report.outputs = outputs;
        Ok(report)
    }

    /// Streaming execution with a sink: `sink` receives each
    /// [`ShardResult`] in stream order as soon as its prefix is complete
    /// (not after a global join), so results can be forwarded or folded
    /// with memory bounded by the ingest budget end to end. The returned
    /// report carries the merged metrics; its `outputs` is empty.
    pub fn run_stream_with<F, S, K>(
        &self,
        factory: &F,
        source: S,
        mut sink: K,
    ) -> Result<ExecReport<F::Out>>
    where
        F: PipelineFactory,
        F::In: Send,
        S: RegionSource<Region = F::In>,
        K: FnMut(ShardResult<F::Out>) -> Result<()>,
    {
        self.cfg.validate()?;
        if self.cfg.max_region_items > 0 {
            return self.run_stream_split(factory, source, sink);
        }
        let mut builder = ReportBuilder::new();
        let run = self
            .pool()
            .run_stream_collect(factory, source, &self.cfg.ingest, |r| {
                builder.add_stats(&r);
                sink(r)
            })?;
        let mut report = builder.finish(run.elapsed);
        report.mark_retired(&run.retired);
        if self.cfg.trace.is_some() {
            Self::attach_trace(&mut report, run.traces);
        }
        Self::attach_metrics(&mut report, self.cfg.workers, run.metrics);
        Ok(report)
    }

    /// [`ShardedRunner::run_stream_with`] with intra-region splitting:
    /// a [`SplitSource`] cuts oversized regions on the fly (everything
    /// else passes through untouched), parts run as first-class regions
    /// under the same bounded in-flight budget, and a [`RegionFolder`]
    /// re-folds each split region's rows before the sink sees them.
    /// Source, folder and sink all run on the driver thread, so the
    /// split ledger needs no locking.
    fn run_stream_split<F, S, K>(
        &self,
        factory: &F,
        source: S,
        mut sink: K,
    ) -> Result<ExecReport<F::Out>>
    where
        F: PipelineFactory,
        F::In: Send,
        S: RegionSource<Region = F::In>,
        K: FnMut(ShardResult<F::Out>) -> Result<()>,
    {
        let split = Self::require_splittable(factory)?;
        let record = split == Splittability::RegionFold;
        let queue: SharedSplitQueue = Rc::new(RefCell::new(SplitQueue::new(record)));
        let source = SplitSource::new(factory, source, self.cfg.max_region_items, queue.clone());
        let mut folder = record.then(|| RegionFolder::new(queue.clone()));
        let mut builder = ReportBuilder::new();
        let run = self
            .pool()
            .run_stream_collect(factory, source, &self.cfg.ingest, |mut r| {
                if let Some(folder) = folder.as_mut() {
                    folder.fold_shard(factory, &mut r)?;
                }
                builder.add_stats(&r);
                sink(r)
            })?;
        if let Some(folder) = folder.as_mut() {
            folder.finish()?;
        }
        let mut report = builder.finish(run.elapsed);
        report.split_regions = queue.borrow().regions_split();
        if let Some(folder) = folder.as_mut() {
            report.partial_regions = folder.take_partials();
        }
        report.mark_retired(&run.retired);
        if self.cfg.trace.is_some() {
            Self::attach_trace(&mut report, run.traces);
        }
        Self::attach_metrics(&mut report, self.cfg.workers, run.metrics);
        Ok(report)
    }

    /// Streaming execution into a [`ResultSink`]: each shard's outputs
    /// are written as soon as the stream-order prefix completes, so with
    /// a file-backed source on one side and a file sink on the other the
    /// whole run — read, compute, write — holds memory bounded by the
    /// ingest budget, never by input or output size. The sink is **not**
    /// finished here: call [`ResultSink::finish`] after the run to flush
    /// and collect [`SinkStats`](crate::io::SinkStats).
    ///
    /// [`ResultSink`]: crate::io::ResultSink
    /// [`ResultSink::finish`]: crate::io::ResultSink::finish
    pub fn run_stream_into<F, S, K>(
        &self,
        factory: &F,
        source: S,
        sink: &mut K,
    ) -> Result<ExecReport<F::Out>>
    where
        F: PipelineFactory,
        F::In: Send,
        S: RegionSource<Region = F::In>,
        K: crate::io::ResultSink<F::Out> + ?Sized,
    {
        self.run_stream_with(factory, source, |r| sink.write_batch(&r.outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::factory::{ShardOutput, ShardWorker};
    use crate::workload::source::SliceSource;
    use anyhow::Result;

    /// Weighted toy: regions are `(id, weight)`; output echoes ids.
    struct WeightedFactory;

    struct EchoWorker;

    impl ShardWorker for EchoWorker {
        type In = (u32, usize);
        type Out = u32;

        fn run_shard(&mut self, shard: &[(u32, usize)]) -> Result<ShardOutput<u32>> {
            Ok(ShardOutput {
                outputs: shard.iter().map(|&(id, _)| id).collect(),
                metrics: Default::default(),
                invocations: 0,
            })
        }
    }

    impl PipelineFactory for WeightedFactory {
        type In = (u32, usize);
        type Out = u32;
        type Worker = EchoWorker;

        fn make_worker(&self, _worker_id: usize) -> Result<EchoWorker> {
            Ok(EchoWorker)
        }

        fn weight(&self, item: &(u32, usize)) -> usize {
            item.1
        }
    }

    fn stream_of(n: u32) -> Vec<(u32, usize)> {
        (0..n).map(|i| (i, 1 + (i as usize % 13))).collect()
    }

    #[test]
    fn runner_preserves_stream_order_for_any_worker_count() {
        let stream = stream_of(500);
        let expect: Vec<u32> = (0..500).collect();
        for workers in 1..=8 {
            let report = ShardedRunner::with_workers(workers)
                .run(&WeightedFactory, &stream)
                .unwrap();
            assert_eq!(report.outputs, expect, "workers={workers}");
            assert!(report.shards <= workers.max(1));
            assert!(report.elapsed >= 0.0);
        }
    }

    #[test]
    fn streaming_matches_materialized_run() {
        let stream = stream_of(400);
        for workers in [1usize, 2, 5, 8] {
            let cfg = ExecConfig::new(workers).streaming(32);
            let materialized = ShardedRunner::new(cfg.clone())
                .run(&WeightedFactory, &stream)
                .unwrap();
            let streamed = ShardedRunner::new(cfg)
                .run_stream(&WeightedFactory, SliceSource::new(&stream))
                .unwrap();
            assert_eq!(streamed.outputs, materialized.outputs, "workers={workers}");
            assert!(streamed.shards >= materialized.shards, "finer granules");
        }
    }

    #[test]
    fn streaming_sink_sees_stream_order_and_report_stays_lean() {
        let stream = stream_of(300);
        let mut next_shard = 0usize;
        let mut sunk: Vec<u32> = Vec::new();
        let report = ShardedRunner::new(ExecConfig::new(4).streaming(16))
            .run_stream_with(&WeightedFactory, SliceSource::new(&stream), |r| {
                assert_eq!(r.shard, next_shard, "sink sees stream order");
                next_shard += 1;
                sunk.extend(r.outputs);
                Ok(())
            })
            .unwrap();
        assert_eq!(sunk, (0..300).collect::<Vec<u32>>());
        assert!(report.outputs.is_empty(), "sink consumed the outputs");
        assert_eq!(report.shards, next_shard);
    }

    #[test]
    fn empty_stream_yields_empty_report() {
        let report = ShardedRunner::with_workers(4).run(&WeightedFactory, &[]).unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.shards, 0);
        let report = ShardedRunner::with_workers(4)
            .run_stream(&WeightedFactory, SliceSource::new(&[]))
            .unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.shards, 0);
    }

    #[test]
    fn zero_workers_is_a_named_error_not_a_clamp() {
        let cfg = ExecConfig::new(0);
        assert_eq!(cfg.workers, 0, "no silent clamp");
        let err = ShardedRunner::new(cfg.clone())
            .run(&WeightedFactory, &stream_of(10))
            .unwrap_err();
        assert!(err.to_string().contains("workers = 0"), "{err}");
        let err = ShardedRunner::new(cfg)
            .run_stream(&WeightedFactory, SliceSource::new(&stream_of(10)))
            .unwrap_err();
        assert!(err.to_string().contains("workers = 0"), "{err}");
    }

    #[test]
    fn exec_config_builders() {
        let c = ExecConfig::new(3).with_shards_per_worker(4);
        assert_eq!(c.shard.shards_per_worker, 4);
        let c = ExecConfig::new(2).streaming(64);
        assert_eq!(c.ingest.buffer_regions, 64);
        let c = ExecConfig::new(2).with_claim(ClaimMode::Cursor);
        assert_eq!(c.claim, ClaimMode::Cursor);
        let c = ExecConfig::new(2).with_fault(FaultPolicy::retry(3));
        assert_eq!(c.fault.max_attempts(), 3);
        let c = ExecConfig::new(2).with_watchdog(Duration::from_secs(5));
        assert_eq!(c.watchdog, Duration::from_secs(5));
        let c = ExecConfig::new(2).with_max_region_items(512);
        assert_eq!(c.max_region_items, 512);
        let c = ExecConfig::new(2).with_metrics(true);
        assert!(c.metrics);
        let c = ExecConfig::new(2).with_progress(Some(Duration::from_secs(2)));
        assert_eq!(c.progress, Some(Duration::from_secs(2)));
        assert!(c.validate().is_ok());
        assert!(!ExecConfig::new(1).metrics, "metrics off by default");
        assert!(ExecConfig::new(1).progress.is_none(), "no heartbeat by default");
        assert_eq!(ExecConfig::new(1).max_region_items, 0, "splitting off by default");
        assert_eq!(ExecConfig::new(1).fault, FaultPolicy::FailFast, "fail-fast by default");
        assert_eq!(ExecConfig::new(1).watchdog, DEFAULT_WATCHDOG);
        assert!(ExecConfig::auto().workers >= 1);
        assert!(ExecConfig::auto().validate().is_ok());
        assert!(ExecConfig::new(0).validate().is_err());
    }

    #[test]
    fn zero_retry_attempts_and_zero_watchdog_are_named_errors() {
        let err = ExecConfig::new(1)
            .with_fault(FaultPolicy::Retry {
                max_attempts: 0,
                backoff: Duration::ZERO,
            })
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("max_attempts = 0"), "{err}");
        let err = ExecConfig::new(1).with_watchdog(Duration::ZERO).validate().unwrap_err();
        assert!(err.to_string().contains("watchdog deadline = 0"), "{err}");
        assert!(ExecConfig::new(1).with_fault(FaultPolicy::retry(1)).validate().is_ok());
        let err = ExecConfig::new(1)
            .with_progress(Some(Duration::ZERO))
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("heartbeat period = 0"), "{err}");
    }

    #[test]
    fn metered_runs_attach_a_reconciling_report_and_stay_bit_identical() {
        let stream = stream_of(300);
        let plain = ShardedRunner::with_workers(3).run(&WeightedFactory, &stream).unwrap();
        assert!(plain.metrics_report.is_none(), "unmetered report carries none");

        let cfg = ExecConfig::new(3).with_metrics(true);
        let metered = ShardedRunner::new(cfg.clone()).run(&WeightedFactory, &stream).unwrap();
        assert_eq!(metered.outputs, plain.outputs, "metering never changes outputs");
        let mr = metered.metrics_report.as_ref().expect("metered report attaches");
        assert_eq!(mr.workers, 3);
        assert_eq!(mr.totals.shards, metered.shards as u64);
        assert_eq!(mr.totals.regions, 300);
        assert_eq!(mr.totals.e2e.count, 0, "no submit stamps when materialized");

        let streamed = ShardedRunner::new(cfg.streaming(32))
            .run_stream(&WeightedFactory, SliceSource::new(&stream))
            .unwrap();
        assert_eq!(streamed.outputs, plain.outputs);
        let mr = streamed.metrics_report.as_ref().expect("streaming report attaches");
        assert_eq!(mr.totals.submitted_regions, 300);
        assert_eq!(mr.totals.emitted_regions, 300);
        assert_eq!(mr.totals.e2e.count, 300, "one e2e sample per region");
        assert_eq!(mr.totals.shards, streamed.shards as u64);
    }

    #[test]
    fn traced_config_attaches_a_reconciling_trace() {
        let stream = stream_of(100);
        let cfg = ExecConfig::new(3).with_trace(Some(crate::trace::TraceOptions::default()));
        let traced = ShardedRunner::new(cfg.clone()).run(&WeightedFactory, &stream).unwrap();
        let trace = traced.trace.as_ref().expect("trace attached when configured");
        assert_eq!(trace.dropped(), 0);
        assert_eq!(trace.shards(), traced.shards as u64);

        let streamed = ShardedRunner::new(cfg)
            .run_stream(&WeightedFactory, SliceSource::new(&stream))
            .unwrap();
        let trace = streamed.trace.as_ref().expect("trace attached when configured");
        assert_eq!(trace.shards(), streamed.shards as u64);
        assert_eq!(trace.submits(), trace.shards());
        assert_eq!(trace.emits(), trace.shards());

        // outputs identical traced vs untraced, and untraced reports
        // carry no trace at all
        let untraced = ShardedRunner::with_workers(3).run(&WeightedFactory, &stream).unwrap();
        assert!(untraced.trace.is_none());
        assert_eq!(untraced.outputs, traced.outputs);
    }

    #[test]
    fn opaque_factory_refuses_splitting_by_name_even_below_threshold() {
        // WeightedFactory keeps the default Opaque splittability; a split
        // threshold must refuse eagerly on both paths — even at a
        // threshold no region reaches, so a config that *would* reorder
        // on bigger inputs never half-works
        let cfg = ExecConfig::new(2).with_max_region_items(10_000);
        let err = ShardedRunner::new(cfg.clone())
            .run(&WeightedFactory, &stream_of(10))
            .unwrap_err();
        assert!(err.to_string().contains("region splitting refused"), "{err}");
        assert!(err.to_string().contains("order-dependent"), "{err}");
        let err = ShardedRunner::new(cfg)
            .run_stream(&WeightedFactory, SliceSource::new(&stream_of(10)))
            .unwrap_err();
        assert!(err.to_string().contains("region splitting refused"), "{err}");
    }

    #[test]
    fn zero_ingest_buffer_is_a_named_error_not_a_clamp() {
        let cfg = ExecConfig::new(2).streaming(0);
        assert_eq!(cfg.ingest.buffer_regions, 0, "no silent clamp");
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("buffer_regions = 0"), "{err}");
        let err = ShardedRunner::new(cfg)
            .run_stream(&WeightedFactory, SliceSource::new(&stream_of(10)))
            .unwrap_err();
        assert!(err.to_string().contains("buffer_regions = 0"), "{err}");
        // materialized runs validate the same config object
        let err = ShardedRunner::new(ExecConfig::new(2).streaming(0))
            .run(&WeightedFactory, &stream_of(10))
            .unwrap_err();
        assert!(err.to_string().contains("buffer_regions = 0"), "{err}");
    }

    #[test]
    fn absurd_ingest_buffer_is_a_named_error() {
        let cfg = ExecConfig::new(2).streaming(MAX_INGEST_BUFFER + 1);
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("sanity cap"), "{err}");
        assert!(ExecConfig::new(2).streaming(MAX_INGEST_BUFFER).validate().is_ok());
        assert!(ExecConfig::new(2).streaming(1).validate().is_ok());
    }

    #[test]
    fn run_stream_into_feeds_the_sink_in_stream_order() {
        use crate::io::{JsonlSink, ResultSink};
        struct CountSink {
            batches: usize,
            records: Vec<u32>,
        }
        impl ResultSink<u32> for CountSink {
            fn write_batch(&mut self, outputs: &[u32]) -> Result<()> {
                self.batches += 1;
                self.records.extend_from_slice(outputs);
                Ok(())
            }
            fn finish(&mut self) -> Result<crate::io::SinkStats> {
                Ok(crate::io::SinkStats::default())
            }
        }
        let stream = stream_of(200);
        let mut sink = CountSink {
            batches: 0,
            records: Vec::new(),
        };
        let report = ShardedRunner::new(ExecConfig::new(3).streaming(16))
            .run_stream_into(&WeightedFactory, SliceSource::new(&stream), &mut sink)
            .unwrap();
        assert_eq!(sink.records, (0..200).collect::<Vec<u32>>());
        assert_eq!(sink.batches, report.shards);
        assert!(report.outputs.is_empty(), "sink consumed the outputs");
        // the JSONL sink slots straight in for (u64, f64) outputs
        let mut jsonl = JsonlSink::new(Vec::new());
        ResultSink::<(u64, f64)>::write_batch(&mut jsonl, &[(1, 2.0)]).unwrap();
    }
}
