//! The executor front door: [`ExecConfig`] (how many workers, how to
//! shard) and [`ShardedRunner`] (plan → pool → merge).

use std::time::Instant;

use anyhow::Result;

use super::factory::PipelineFactory;
use super::merge::{merge_results, ExecReport};
use super::plan::{ShardPlan, ShardPolicy};
use super::pool::WorkerPool;

/// Executor configuration.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// Worker threads (pipeline replicas). 1 = run inline.
    pub workers: usize,
    /// Shard-planning policy.
    pub shard: ShardPolicy,
}

impl ExecConfig {
    /// `workers` threads with the default (one shard per worker) policy.
    pub fn new(workers: usize) -> ExecConfig {
        ExecConfig {
            workers: workers.max(1),
            shard: ShardPolicy::default(),
        }
    }

    /// One worker per available CPU.
    pub fn auto() -> ExecConfig {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        ExecConfig::new(workers)
    }

    /// Builder-style override of the shard policy.
    pub fn with_shards_per_worker(mut self, shards_per_worker: usize) -> ExecConfig {
        self.shard.shards_per_worker = shards_per_worker.max(1);
        self
    }
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig::new(1)
    }
}

/// Runs a [`PipelineFactory`]'s pipeline over a region stream, sharded
/// across workers, and merges the results deterministically.
#[derive(Debug, Clone)]
pub struct ShardedRunner {
    cfg: ExecConfig,
}

impl ShardedRunner {
    pub fn new(cfg: ExecConfig) -> ShardedRunner {
        ShardedRunner { cfg }
    }

    /// Shorthand for `ShardedRunner::new(ExecConfig::new(workers))`.
    pub fn with_workers(workers: usize) -> ShardedRunner {
        ShardedRunner::new(ExecConfig::new(workers))
    }

    pub fn config(&self) -> &ExecConfig {
        &self.cfg
    }

    /// Plan shards at region boundaries, fan them out over the worker
    /// pool, and merge outputs back into stream order.
    pub fn run<F: PipelineFactory>(
        &self,
        factory: &F,
        stream: &[F::In],
    ) -> Result<ExecReport<F::Out>> {
        let t0 = Instant::now();
        let weights: Vec<usize> = stream.iter().map(|r| factory.weight(r)).collect();
        let plan = ShardPlan::build(&weights, self.cfg.workers, &self.cfg.shard);
        let results = WorkerPool::new(self.cfg.workers).run(factory, stream, &plan)?;
        Ok(merge_results(results, t0.elapsed().as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::factory::{ShardOutput, ShardWorker};
    use anyhow::Result;

    /// Weighted toy: regions are `(id, weight)`; output echoes ids.
    struct WeightedFactory;

    struct EchoWorker;

    impl ShardWorker for EchoWorker {
        type In = (u32, usize);
        type Out = u32;

        fn run_shard(&mut self, shard: &[(u32, usize)]) -> Result<ShardOutput<u32>> {
            Ok(ShardOutput {
                outputs: shard.iter().map(|&(id, _)| id).collect(),
                metrics: Default::default(),
                invocations: 0,
            })
        }
    }

    impl PipelineFactory for WeightedFactory {
        type In = (u32, usize);
        type Out = u32;
        type Worker = EchoWorker;

        fn make_worker(&self, _worker_id: usize) -> Result<EchoWorker> {
            Ok(EchoWorker)
        }

        fn weight(&self, item: &(u32, usize)) -> usize {
            item.1
        }
    }

    #[test]
    fn runner_preserves_stream_order_for_any_worker_count() {
        let stream: Vec<(u32, usize)> = (0..500).map(|i| (i, 1 + (i as usize % 13))).collect();
        let expect: Vec<u32> = (0..500).collect();
        for workers in 1..=8 {
            let report = ShardedRunner::with_workers(workers)
                .run(&WeightedFactory, &stream)
                .unwrap();
            assert_eq!(report.outputs, expect, "workers={workers}");
            assert!(report.shards <= workers.max(1));
            assert!(report.elapsed >= 0.0);
        }
    }

    #[test]
    fn empty_stream_yields_empty_report() {
        let report = ShardedRunner::with_workers(4)
            .run(&WeightedFactory, &[])
            .unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.shards, 0);
    }

    #[test]
    fn exec_config_builders() {
        let c = ExecConfig::new(0);
        assert_eq!(c.workers, 1);
        let c = ExecConfig::new(3).with_shards_per_worker(4);
        assert_eq!(c.shard.shards_per_worker, 4);
        assert!(ExecConfig::auto().workers >= 1);
    }
}
