//! How apps hand the executor a pipeline: a [`PipelineFactory`] describes
//! how to build a fresh, fully private pipeline instance inside a worker
//! thread, and the [`ShardWorker`] it returns runs one shard at a time.
//! A worker's pipeline is built **once** (in `make_worker`) and lives as
//! long as the worker: `run_shard` resets the persistent graph between
//! shards instead of rebuilding it, and
//! [`ShardWorker::pipelines_built`] reports the build count so reports
//! can prove builds scale with workers, not shards.
//!
//! The coordinator is `Rc`-based and single-threaded by design; nothing in
//! it is `Send`. The factory is the seam that keeps it that way: the
//! factory itself crosses threads (`Sync`), the worker it builds never
//! does — it is created, used and dropped inside one scoped thread.
//! [`KernelSpawn`] plays the same role for kernel sets: PJRT client
//! handles are thread-confined, so each worker owns its own engine
//! (mirroring one CUDA context per SM in the paper's machine mapping).

use std::rc::Rc;

use anyhow::Result;

use crate::coordinator::metrics::PipelineMetrics;
use crate::runtime::kernels::{Backend, KernelSet};
use crate::runtime::{ArtifactStore, Engine};
use crate::trace::TraceSink;

/// What one shard produced: outputs in stream order plus the shard
/// pipeline's metrics and kernel-invocation count.
#[derive(Debug, Clone)]
pub struct ShardOutput<T> {
    /// Pipeline outputs, in the shard's stream order.
    pub outputs: Vec<T>,
    /// Metrics of the pipeline instance that ran this shard.
    pub metrics: PipelineMetrics,
    /// Kernel invocations spent on this shard (the SIMD cost unit).
    pub invocations: u64,
}

/// A per-worker pipeline instance. Not `Send`: it lives and dies inside
/// one worker thread, and typically owns `Rc`-based coordinator state
/// plus a thread-confined kernel engine.
pub trait ShardWorker {
    /// Region/composite type consumed from the shared stream.
    type In;
    /// Output item type.
    type Out;

    /// Run one shard (a contiguous slice of the input stream) through a
    /// fresh-or-reused pipeline to quiescence.
    fn run_shard(&mut self, shard: &[Self::In]) -> Result<ShardOutput<Self::Out>>;

    /// The pool announces the stream-order index of the shard it is
    /// about to run (again before every retry attempt). Workers don't
    /// need it to execute — shards arrive as plain slices — but the
    /// fault-injection harness keys its planned faults on this index,
    /// and a worker may use it for diagnostics. Default: ignored.
    fn begin_shard(&mut self, shard: usize) {
        let _ = shard;
    }

    /// Cumulative node-graph builds this worker has performed so far —
    /// the zero-rebuild proof. A persistent worker builds once in
    /// `make_worker` and reports 1 however many shards it runs; a worker
    /// that rebuilds per `run_shard` reports the build count. The pool
    /// samples this after every shard and the merge folds it per worker
    /// ([`WorkerStats::pipelines_built`](super::merge::WorkerStats)), so
    /// `ExecReport::pipelines_built == workers` is testable end to end.
    fn pipelines_built(&self) -> u64 {
        1
    }

    /// Install a trace sink into the worker's pipeline so scheduler
    /// firings are recorded (see [`crate::trace`]). Called once per
    /// worker, right after `make_worker`, when the pool runs traced;
    /// the default ignores it, so untraceable workers still execute
    /// correctly (their firings simply don't appear in the trace).
    fn set_trace(&mut self, sink: TraceSink) {
        let _ = sink;
    }
}

/// Describes how to instantiate one pipeline per worker. Shared by
/// reference across worker threads, so it must be `Sync`; the workers it
/// makes need not be.
pub trait PipelineFactory: Sync {
    /// Region/composite type of the input stream.
    type In: Sync;
    /// Output item type (crosses back to the caller's thread).
    type Out: Send;
    /// The per-thread pipeline instance.
    type Worker: ShardWorker<In = Self::In, Out = Self::Out>;

    /// Build a fresh pipeline (and kernel engine) for worker `worker_id`.
    /// Called once, inside the worker's own thread, during the pool's
    /// prewarm phase — before the timed claim loop starts, so the first
    /// shard never pays graph construction inside the measurement. The
    /// returned worker's pipeline is expected to persist across every
    /// shard that worker runs (reset, not rebuild).
    fn make_worker(&self, worker_id: usize) -> Result<Self::Worker>;

    /// Item weight of one region, used by the shard planner to balance
    /// shards (default: every region counts 1).
    fn weight(&self, _item: &Self::In) -> usize {
        1
    }

    /// Reclaim one region after its shard completes (streaming runs
    /// only; called on the executing worker's thread). The default
    /// drops the region; a factory that shares a
    /// [`ContainerPool`](super::ingest::ContainerPool) with its source
    /// returns the region's heap buffers instead — closing the recycling
    /// loop that makes file-backed ingest allocation-free end to end
    /// (`SumFactory::with_elem_pool` + `BlobFileSource::with_pool`).
    fn recycle_region(&self, region: Self::In) {
        drop(region);
    }

    /// Whether this factory's regions may be cut into sub-shards for
    /// intra-region parallelism (default: no — region state is assumed
    /// order-dependent until a factory proves otherwise). See
    /// [`Splittability`] and [`crate::exec::split`].
    fn splittability(&self) -> Splittability {
        Splittability::Opaque {
            reason: "region state is assumed order-dependent unless the factory opts in",
        }
    }

    /// Cut one region into **owned** parts of at most `max_items` weight
    /// each, preserving item order (part 0 holds the region's first
    /// items). A region at or under the threshold comes back as a single
    /// owned part (typically a clone), so the runner never needs a
    /// `Clone` bound of its own. Must return at least one part. The
    /// default refuses: a factory that advertises a splittable
    /// [`Splittability`] must override it.
    fn split_region(&self, region: &Self::In, max_items: usize) -> Result<Vec<Self::In>> {
        let _ = (region, max_items);
        anyhow::bail!("split_region not implemented for this factory")
    }

    /// Fold one part's output row into the accumulated row for its
    /// region, in ascending part order (left-linear). Required by
    /// [`Splittability::RegionFold`]; the fold must replay the exact
    /// reduction the unsplit pipeline performs so the combined result is
    /// bit-identical. The default refuses.
    fn combine(&self, acc: &mut Self::Out, part: Self::Out) -> Result<()> {
        let _ = (acc, part);
        anyhow::bail!("combine not implemented for this factory")
    }
}

/// Whether (and how) a factory's regions may be cut into sub-shards for
/// intra-region parallelism (see [`crate::exec::split`]).
///
/// A region is the unit of cross-item state, so splitting one is only
/// legal when the stage's state is an **associative accumulator** that
/// can be folded from per-part partials in a fixed order. Factories
/// advertise which contract they satisfy; the runner refuses to split
/// anything `Opaque`, naming the reason, rather than silently producing
/// reordered results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Splittability {
    /// The stage carries order-dependent (or otherwise non-associative)
    /// region state; splitting would change results. `reason` names the
    /// specific dependency so the refusal error is actionable.
    Opaque {
        /// Why this stage cannot split (surfaced verbatim in the error).
        reason: &'static str,
    },
    /// Each region produces exactly **one** output row, and a split
    /// region's rows are re-folded left-to-right in part order by
    /// [`PipelineFactory::combine`] before stream-order emission. The
    /// combine must replay the same reduction the unsplit pipeline
    /// performs, so the folded result is bit-identical.
    RegionFold,
    /// Outputs are already globally folded downstream of the executor
    /// (e.g. tagged sums coalesced after the run), so part rows can pass
    /// straight through the merge — no per-region fold needed. The
    /// stage's accuracy contract must already tolerate shard-boundary
    /// regrouping.
    GlobalFold,
}

impl Splittability {
    /// True when the runner may cut this factory's regions.
    pub fn allows_split(&self) -> bool {
        !matches!(self, Splittability::Opaque { .. })
    }
}

/// Per-thread kernel-set recipe: which backend every worker should build
/// its private [`KernelSet`] on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelSpawn {
    /// Pure-Rust kernel mirror — thread-safe to build anywhere.
    Native,
    /// AOT artifacts through PJRT — each worker creates its own engine
    /// (client handles are thread-confined).
    Xla,
}

/// A worker's kernel set, keeping its PJRT engine (if any) alive.
pub struct WorkerKernels {
    /// Kernel set shared by the worker's pipeline nodes.
    pub kernels: Rc<KernelSet>,
    _engine: Option<Engine>,
}

impl KernelSpawn {
    /// The spawn recipe matching an existing kernel set's backend.
    pub fn from_backend(backend: Backend) -> KernelSpawn {
        match backend {
            Backend::Native => KernelSpawn::Native,
            Backend::Xla => KernelSpawn::Xla,
        }
    }

    /// Build a kernel set at `width` inside the calling thread.
    pub fn spawn(self, width: usize) -> Result<WorkerKernels> {
        match self {
            KernelSpawn::Native => Ok(WorkerKernels {
                kernels: Rc::new(KernelSet::native(width)),
                _engine: None,
            }),
            KernelSpawn::Xla => {
                let engine = Engine::new(ArtifactStore::discover()?)?;
                let kernels = Rc::new(KernelSet::xla(&engine, width)?);
                Ok(WorkerKernels {
                    kernels,
                    _engine: Some(engine),
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_spawn_builds_per_thread_kernels() {
        let wk = KernelSpawn::Native.spawn(8).unwrap();
        assert_eq!(wk.kernels.width(), 8);
        assert_eq!(wk.kernels.backend(), Backend::Native);
    }

    #[test]
    fn spawn_matches_backend() {
        assert_eq!(
            KernelSpawn::from_backend(Backend::Native),
            KernelSpawn::Native
        );
        assert_eq!(KernelSpawn::from_backend(Backend::Xla), KernelSpawn::Xla);
    }
}
