//! Shard planning: partition a region stream into contiguous per-worker
//! shards, cutting **only at region boundaries**.
//!
//! The planner sees the stream as a sequence of region *weights* (element
//! counts) and produces contiguous index ranges. Contiguity is what makes
//! the downstream merge trivial and deterministic: concatenating shard
//! outputs in shard order *is* original stream order.
//!
//! Balancing is greedy: each shard is closed once it reaches the ideal
//! share of the remaining weight, recomputed as shards close (so one huge
//! region early in the stream does not starve the tail). A shard is never
//! empty and a region is never split — see the invariant in
//! [`super`]'s module docs.

/// Tunables for [`ShardPlan::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPolicy {
    /// Shards to aim for per worker. `1` gives the most deterministic
    /// layout (and exact single-run equivalence at `workers = 1`); larger
    /// values give the pool slack to balance load dynamically when shard
    /// costs are skewed.
    pub shards_per_worker: usize,
    /// Hard cap on total shards, whatever the worker count asks for.
    pub max_shards: usize,
    /// Don't create shards lighter than this many items (prevents
    /// pathological splintering of tiny streams). `1` disables.
    pub min_shard_items: usize,
}

impl Default for ShardPolicy {
    fn default() -> Self {
        ShardPolicy {
            shards_per_worker: 1,
            max_shards: 1024,
            min_shard_items: 1,
        }
    }
}

/// A boundary-respecting partition of `0..n` regions into contiguous
/// shards, plus the per-shard weights the planner balanced on.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    ranges: Vec<std::ops::Range<usize>>,
    weights: Vec<usize>,
    total_weight: usize,
}

fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

impl ShardPlan {
    /// Plan shards for a stream of `region_weights.len()` regions.
    ///
    /// Produces `min(workers × shards_per_worker, max_shards, n_regions)`
    /// shards (further reduced if `min_shard_items` demands it), each a
    /// non-empty contiguous range. An empty stream yields an empty plan.
    pub fn build(region_weights: &[usize], workers: usize, policy: &ShardPolicy) -> ShardPlan {
        let n = region_weights.len();
        let total: usize = region_weights.iter().sum();
        if n == 0 {
            return ShardPlan {
                ranges: Vec::new(),
                weights: Vec::new(),
                total_weight: 0,
            };
        }
        let mut k = workers
            .max(1)
            .saturating_mul(policy.shards_per_worker.max(1))
            .min(policy.max_shards.max(1))
            .min(n);
        if policy.min_shard_items > 1 {
            k = k.min((total / policy.min_shard_items).max(1));
        }

        let mut ranges = Vec::with_capacity(k);
        let mut weights = Vec::with_capacity(k);
        let mut remaining_weight = total;
        let mut remaining_shards = k;
        let mut target = ceil_div(remaining_weight.max(1), remaining_shards);
        let mut start = 0usize;
        let mut acc = 0usize;
        for (i, &w) in region_weights.iter().enumerate() {
            acc += w;
            let regions_after = n - i - 1;
            // Close the current shard when it has met its target — or when
            // postponing would leave fewer regions than open shards (every
            // shard must get at least one region).
            let must_close = regions_after == remaining_shards - 1 && remaining_shards > 1;
            let close = remaining_shards > 1 && (acc >= target || must_close);
            if close || i == n - 1 {
                ranges.push(start..i + 1);
                weights.push(acc);
                remaining_weight -= acc;
                remaining_shards -= 1;
                start = i + 1;
                acc = 0;
                if remaining_shards > 0 {
                    target = ceil_div(remaining_weight.max(1), remaining_shards);
                }
            }
        }
        debug_assert_eq!(ranges.len(), k);
        ShardPlan {
            ranges,
            weights,
            total_weight: total,
        }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    /// Whether the plan has no shards.
    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// Region-index range of shard `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        self.ranges[i].clone()
    }

    /// All ranges in shard order.
    pub fn ranges(&self) -> &[std::ops::Range<usize>] {
        &self.ranges
    }

    /// Total item weight of shard `i`.
    pub fn shard_weight(&self, i: usize) -> usize {
        self.weights[i]
    }

    /// Total item weight across the stream.
    pub fn total_weight(&self) -> usize {
        self.total_weight
    }

    /// Balance quality: heaviest shard weight over the ideal equal share
    /// (1.0 = perfect; large regions force it higher).
    pub fn imbalance(&self) -> f64 {
        if self.ranges.is_empty() || self.total_weight == 0 {
            return 1.0;
        }
        let max = self.weights.iter().copied().max().unwrap_or(0) as f64;
        let ideal = self.total_weight as f64 / self.ranges.len() as f64;
        max / ideal
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn check_invariants(weights: &[usize], plan: &ShardPlan) {
        // contiguous cover of 0..n, in order, no empty shard
        let mut next = 0usize;
        for i in 0..plan.len() {
            let r = plan.range(i);
            assert_eq!(r.start, next, "shards must be contiguous");
            assert!(r.end > r.start, "no empty shards");
            assert_eq!(
                plan.shard_weight(i),
                weights[r.clone()].iter().sum::<usize>(),
                "shard weight bookkeeping"
            );
            next = r.end;
        }
        assert_eq!(next, weights.len(), "shards must cover the stream");
        assert_eq!(
            plan.total_weight(),
            weights.iter().sum::<usize>(),
            "total weight"
        );
    }

    #[test]
    fn single_worker_single_shard() {
        let w = vec![5usize; 10];
        let plan = ShardPlan::build(&w, 1, &ShardPolicy::default());
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.range(0), 0..10);
        check_invariants(&w, &plan);
    }

    #[test]
    fn balances_uniform_weights() {
        let w = vec![10usize; 100];
        let plan = ShardPlan::build(&w, 4, &ShardPolicy::default());
        assert_eq!(plan.len(), 4);
        check_invariants(&w, &plan);
        for i in 0..4 {
            assert_eq!(plan.shard_weight(i), 250);
        }
        assert!((plan.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn never_splits_a_heavy_region() {
        // one region dwarfs the rest: it must land whole in one shard
        let mut w = vec![1usize; 20];
        w[3] = 1000;
        let plan = ShardPlan::build(&w, 4, &ShardPolicy::default());
        check_invariants(&w, &plan);
        let heavy = (0..plan.len()).find(|&i| plan.range(i).contains(&3)).unwrap();
        assert!(plan.shard_weight(heavy) >= 1000);
    }

    #[test]
    fn more_workers_than_regions() {
        let w = vec![7usize; 3];
        let plan = ShardPlan::build(&w, 16, &ShardPolicy::default());
        assert_eq!(plan.len(), 3, "at most one shard per region");
        check_invariants(&w, &plan);
    }

    #[test]
    fn max_shards_cap_applies() {
        let w = vec![1usize; 100];
        let plan = ShardPlan::build(
            &w,
            16,
            &ShardPolicy {
                shards_per_worker: 8,
                max_shards: 5,
                min_shard_items: 1,
            },
        );
        assert_eq!(plan.len(), 5);
        check_invariants(&w, &plan);
    }

    #[test]
    fn min_shard_items_prevents_splintering() {
        let w = vec![1usize; 8]; // 8 items total
        let plan = ShardPlan::build(
            &w,
            8,
            &ShardPolicy {
                shards_per_worker: 1,
                max_shards: 1024,
                min_shard_items: 4,
            },
        );
        assert_eq!(plan.len(), 2, "8 items / min 4 per shard");
        check_invariants(&w, &plan);
    }

    #[test]
    fn empty_stream_empty_plan() {
        let plan = ShardPlan::build(&[], 4, &ShardPolicy::default());
        assert!(plan.is_empty());
        assert_eq!(plan.total_weight(), 0);
    }

    #[test]
    fn zero_weight_regions_still_covered() {
        let w = vec![0usize, 0, 5, 0, 3, 0];
        let plan = ShardPlan::build(&w, 3, &ShardPolicy::default());
        check_invariants(&w, &plan);
    }

    #[test]
    fn random_streams_keep_invariants_and_rough_balance() {
        let mut rng = Prng::new(42);
        for _ in 0..50 {
            let n = 1 + rng.below(400);
            let weights: Vec<usize> = (0..n).map(|_| rng.below(64)).collect();
            let workers = 1 + rng.below(12);
            let spw = 1 + rng.below(4);
            let policy = ShardPolicy {
                shards_per_worker: spw,
                ..ShardPolicy::default()
            };
            let plan = ShardPlan::build(&weights, workers, &policy);
            check_invariants(&weights, &plan);
            assert!(plan.len() <= workers * spw);
            // greedy bound: a shard closes at the first region that meets
            // its target, so it exceeds the ideal share by at most the
            // heaviest single region (plus ceil-rounding slack).
            let max_region = weights.iter().copied().max().unwrap_or(0);
            let ideal = ceil_div(plan.total_weight().max(1), plan.len().max(1));
            let slack = plan.len();
            for i in 0..plan.len() {
                assert!(
                    plan.shard_weight(i) <= ideal + max_region + slack,
                    "shard {i} weight {} vs ideal {ideal} + max region {max_region}",
                    plan.shard_weight(i)
                );
            }
        }
    }
}
