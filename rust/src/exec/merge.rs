//! Deterministic merge: reassemble per-shard outputs in original stream
//! order and fold per-shard [`PipelineMetrics`] into one global report
//! with a per-worker breakdown.
//!
//! Because shards are contiguous ranges of the region stream,
//! concatenation in shard-index order *is* stream order — the merge
//! involves no reordering heuristics and is independent of which worker
//! ran what, or when (stealing included). Metrics are folded in shard
//! order too, so the global counters are identical run to run.
//!
//! Two shapes:
//!
//! * [`merge_results`] — the materialized join: all shard results at
//!   once, already sorted.
//! * [`StreamMerger`] — the streaming window: accepts results in
//!   completion order and releases them in stream order as soon as the
//!   prefix is complete, over a fixed pre-allocated ring sized by the
//!   ingest budget (no per-shard allocation). [`ReportBuilder`] folds the
//!   released results into the same [`ExecReport`] incrementally.
//!
//! When a run splits regions (see [`crate::exec::split`]), the
//! [`RegionFolder`] sits upstream of both shapes: it re-folds a split
//! region's consecutive part rows into one row — left-linear, in part
//! order, via the factory's `combine` — before outputs are concatenated
//! or streamed, so the emitted stream is indistinguishable from an
//! unsplit run's.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::coordinator::metrics::PipelineMetrics;
use crate::metrics::{MetricsHub, MetricsReport};
use crate::trace::Trace;

use super::factory::PipelineFactory;
use super::fault::FaultRecord;
use super::pool::ShardResult;
use super::split::SharedSplitQueue;

/// Aggregated execution stats for one worker of a sharded run.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    /// Worker id (0-based).
    pub worker: usize,
    /// Shards this worker executed.
    pub shards: usize,
    /// How many of those it stole from another worker's deque.
    pub steals: usize,
    /// Output items it produced.
    pub outputs: usize,
    /// Kernel invocations it spent.
    pub invocations: u64,
    /// Seconds spent actually running shards (its busy time).
    pub busy: f64,
    /// Node graphs this worker built over its lifetime (the maximum
    /// cumulative count its shard results reported) — 1 for a
    /// persistent reset-not-rebuild worker, regardless of `shards`, plus
    /// one per fault-recovery rebuild.
    pub pipelines_built: u64,
    /// Extra shard attempts this worker ran under
    /// [`FaultPolicy::Retry`](super::fault::FaultPolicy) (0 fault-free).
    pub retries: u64,
    /// Shards this worker quarantined (whole or in part).
    pub faults: u64,
    /// Its pipeline metrics, folded across its shards.
    pub metrics: PipelineMetrics,
    /// The worker retired mid-run: its `Quarantine` rebuild failed, its
    /// unfinished shard was re-dealt to survivors, and it stopped
    /// claiming. Shards it completed *before* retiring are still
    /// counted above.
    pub dead: bool,
}

impl WorkerStats {
    /// A zeroed row for `worker` — the fold seed.
    fn empty(worker: usize) -> WorkerStats {
        WorkerStats {
            worker,
            shards: 0,
            steals: 0,
            outputs: 0,
            invocations: 0,
            busy: 0.0,
            pipelines_built: 0,
            retries: 0,
            faults: 0,
            metrics: PipelineMetrics::default(),
            dead: false,
        }
    }
}

/// One split region that lost parts to a quarantine: the named salvage
/// ledger entry. The region emits **no** output row — these are the
/// pieces that survived, made explicit so a partial aggregate can never
/// masquerade as a total.
#[derive(Debug, Clone, PartialEq)]
pub struct PartialRegion<T> {
    /// The region's stream id (the [`SubShard::region`] ordinal).
    ///
    /// [`SubShard::region`]: super::split::SubShard::region
    pub region: u64,
    /// How many parts the region was split into.
    pub of: u32,
    /// Part indices (ascending) that were lost.
    pub lost: Vec<u32>,
    /// One entry per maximal contiguous run of surviving parts:
    /// `(first part index of the run, left-linear fold of the run)`.
    /// The fold inside each run uses the factory's `combine`, in part
    /// order — bit-identical to the prefix it represents.
    pub salvaged: Vec<(u32, T)>,
}

/// The merged result of a sharded run.
#[derive(Debug, Clone)]
pub struct ExecReport<T> {
    /// All outputs, in original stream order (empty when a streaming
    /// sink consumed them instead).
    pub outputs: Vec<T>,
    /// Global pipeline metrics: every worker's counters folded together
    /// (`elapsed` is the max pipeline-internal time, as in
    /// [`PipelineMetrics::merge`]).
    pub metrics: PipelineMetrics,
    /// Total kernel invocations across workers.
    pub invocations: u64,
    /// Number of shards executed.
    pub shards: usize,
    /// Shards that changed workers via stealing.
    pub steals: usize,
    /// Total node-graph builds across workers. The zero-rebuild
    /// invariant: equals the number of workers that claimed ≥ 1 shard
    /// (`per_worker.len()`), **not** `shards` — each worker builds its
    /// pipeline once and resets it between shards.
    pub pipelines_built: u64,
    /// Total extra shard attempts across workers (one per
    /// rebuild-and-rerun recovery cycle; 0 on a fault-free run). Under
    /// injection this reconciles exactly with the plan's shot count.
    pub retries: u64,
    /// The fault ledger, in stream order: one record per lost region
    /// (part-granular — [`FaultRecord::part`] names the in-shard
    /// ordinal) or per wholly-lost shard, under
    /// [`FaultPolicy::Quarantine`](super::fault::FaultPolicy). Empty on
    /// fault-free, fail-fast and fully-recovered retry runs.
    pub faults: Vec<FaultRecord>,
    /// The salvage ledger for **split** regions that lost parts: each
    /// entry names exactly which parts of the region are gone and
    /// carries the folded partials of every maximal contiguous
    /// surviving run. A region listed here has **no** row in `outputs`
    /// — a partial aggregate is never passed off as a total; callers
    /// that can use salvage must opt in by reading this ledger.
    pub partial_regions: Vec<PartialRegion<T>>,
    /// Single-region re-runs workers performed while narrowing `Retry`
    /// recoveries (0 fault-free). Compare with `retries` × regions/shard
    /// to see what part-level retry saved over whole-shard re-runs.
    pub rerun_regions: u64,
    /// Regions the planner cut into sub-shards for intra-region
    /// parallelism (0 when splitting is off — the default — or when no
    /// region exceeded
    /// [`ExecConfig::max_region_items`](super::runner::ExecConfig)).
    pub split_regions: usize,
    /// Wall-clock seconds of the whole sharded run (plan + pool + merge).
    pub elapsed: f64,
    /// Per-worker breakdown, sorted by worker id (workers that never
    /// claimed a shard are absent).
    pub per_worker: Vec<WorkerStats>,
    /// Folded event trace of the run; `Some` only when the run was
    /// launched with tracing enabled ([`ExecConfig::with_trace`]).
    /// With zero drops its firing/ensemble/item totals reconcile
    /// exactly with `metrics` (see [`crate::trace`]).
    ///
    /// [`ExecConfig::with_trace`]: super::runner::ExecConfig::with_trace
    pub trace: Option<Trace>,
    /// Folded live telemetry (every lane's counters and latency
    /// histograms, exact-merged); `Some` only when the run was launched
    /// with metrics enabled ([`ExecConfig::with_metrics`]). Its shard,
    /// region, steal, retry and fault totals reconcile number for number
    /// with the fields above (`tests/metrics_observe.rs` pins this).
    ///
    /// [`ExecConfig::with_metrics`]: super::runner::ExecConfig::with_metrics
    pub metrics_report: Option<MetricsReport>,
}

impl<T> ExecReport<T> {
    /// Parallel efficiency proxy: total busy time over (wall × workers
    /// observed). 1.0 = every worker busy the whole run.
    pub fn utilization(&self) -> f64 {
        if self.per_worker.is_empty() || self.elapsed <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.per_worker.iter().map(|w| w.busy).sum();
        busy / (self.elapsed * self.per_worker.len() as f64)
    }

    /// Render the per-worker breakdown (used by `--stats`). `occ%` is
    /// SIMD lane occupancy; `idle%` is the share of the run's wall clock
    /// the worker spent not executing shards (claim waits, steal
    /// attempts, end-of-stream drain).
    pub fn worker_table(&self) -> String {
        let mut out = String::from(
            "worker   shards   stolen   built   retry   fault   outputs   kernel_inv   \
             busy_s    occ%   idle%\n",
        );
        for w in &self.per_worker {
            let idle = if self.elapsed > 0.0 {
                100.0 * ((self.elapsed - w.busy).max(0.0) / self.elapsed)
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<8} {:>6}  {:>6}  {:>5}  {:>5}  {:>5}  {:>8}  {:>11}  {:>7.3}  {:>5.1}  \
                 {:>5.1}{}\n",
                w.worker,
                w.shards,
                w.steals,
                w.pipelines_built,
                w.retries,
                w.faults,
                w.outputs,
                w.invocations,
                w.busy,
                100.0 * w.metrics.occupancy(),
                idle,
                if w.dead { "  retired" } else { "" },
            ));
        }
        out
    }

    /// Render the quarantine ledger (used by `--stats`): one line per
    /// lost region (or wholly-lost shard), stream order, with a
    /// granularity column telling the two apart. Empty string when the
    /// run had no faults, so callers can print it unconditionally.
    pub fn fault_table(&self) -> String {
        if self.faults.is_empty() {
            return String::new();
        }
        let mut out = String::from("shard    worker   attempts   granularity   error\n");
        for f in &self.faults {
            out.push_str(&format!(
                "{:<8} {:>6}  {:>8}   {:<11}   {}\n",
                f.shard,
                f.worker,
                f.attempts,
                f.granularity(),
                f.error
            ));
        }
        out
    }

    /// Render the salvage ledger (used by `--stats`): one line per
    /// split region that lost parts, with the lost part indices and the
    /// surviving contiguous runs. Empty string when no region was
    /// partially lost, so callers can print it unconditionally.
    pub fn partial_table(&self) -> String {
        if self.partial_regions.is_empty() {
            return String::new();
        }
        let mut out = String::from("region   parts   lost          salvaged_runs\n");
        for p in &self.partial_regions {
            let lost: Vec<String> = p.lost.iter().map(u32::to_string).collect();
            let runs: Vec<String> =
                p.salvaged.iter().map(|(start, _)| format!("@{start}")).collect();
            out.push_str(&format!(
                "{:<8} {:>5}   {:<12}  {}\n",
                p.region,
                p.of,
                lost.join(","),
                if runs.is_empty() { "-".to_string() } else { runs.join(" ") },
            ));
        }
        out
    }

    /// Mark `retired` workers dead in the per-worker table. A worker
    /// that retired before completing any shard still gets a zeroed
    /// row, so degradation is always visible; the table stays sorted by
    /// worker id.
    pub fn mark_retired(&mut self, retired: &[usize]) {
        for &worker in retired {
            match self.per_worker.iter_mut().find(|w| w.worker == worker) {
                Some(w) => w.dead = true,
                None => {
                    let mut w = WorkerStats::empty(worker);
                    w.dead = true;
                    self.per_worker.push(w);
                }
            }
        }
        self.per_worker.sort_by_key(|w| w.worker);
    }
}

/// Incremental fold of shard results into an [`ExecReport`]: the
/// materialized join and the streaming path share the exact same
/// accounting, so their reports are comparable number for number.
pub struct ReportBuilder<T> {
    outputs: Vec<T>,
    metrics: PipelineMetrics,
    invocations: u64,
    shards: usize,
    steals: usize,
    retries: u64,
    rerun_regions: u64,
    faults: Vec<FaultRecord>,
    per_worker: BTreeMap<usize, WorkerStats>,
}

impl<T> Default for ReportBuilder<T> {
    fn default() -> Self {
        ReportBuilder::new()
    }
}

impl<T> ReportBuilder<T> {
    /// Create an empty builder.
    pub fn new() -> ReportBuilder<T> {
        ReportBuilder {
            outputs: Vec::new(),
            metrics: PipelineMetrics::default(),
            invocations: 0,
            shards: 0,
            steals: 0,
            retries: 0,
            rerun_regions: 0,
            faults: Vec::new(),
            per_worker: BTreeMap::new(),
        }
    }

    /// Mark `worker` as retired (its `Quarantine` rebuild failed and
    /// its remaining work was re-dealt). A worker that retired before
    /// completing any shard still gets a (zeroed) row, so degradation
    /// is always visible in the worker table.
    pub fn mark_dead(&mut self, worker: usize) {
        self.per_worker
            .entry(worker)
            .or_insert_with(|| WorkerStats::empty(worker))
            .dead = true;
    }

    /// Fold one shard's counters (not its outputs — the caller decides
    /// whether outputs are collected or streamed to a sink).
    pub fn add_stats(&mut self, r: &ShardResult<T>) {
        self.metrics.merge(&r.metrics);
        self.invocations += r.invocations;
        self.shards += 1;
        self.steals += r.stolen as usize;
        self.retries += u64::from(r.retries);
        self.rerun_regions += r.rerun_regions;
        if let Some(error) = &r.fault {
            // Part-granular ledger: one record per lost in-shard
            // ordinal. A shard that lost everything (or a legacy result
            // with no part list) folds to a single whole-shard record,
            // so 1-region shards read exactly as before.
            if r.lost.is_empty() || r.lost.len() == r.regions {
                self.faults.push(FaultRecord {
                    shard: r.shard,
                    worker: r.worker,
                    attempts: r.retries + 1,
                    error: error.clone(),
                    part: None,
                });
            } else {
                for &ordinal in &r.lost {
                    self.faults.push(FaultRecord {
                        shard: r.shard,
                        worker: r.worker,
                        attempts: r.retries + 1,
                        error: error.clone(),
                        part: Some(ordinal),
                    });
                }
            }
        }
        let w = self
            .per_worker
            .entry(r.worker)
            .or_insert_with(|| WorkerStats::empty(r.worker));
        w.shards += 1;
        w.steals += r.stolen as usize;
        w.outputs += r.outputs.len();
        w.invocations += r.invocations;
        w.busy += r.elapsed;
        w.retries += u64::from(r.retries);
        w.faults += u64::from(r.fault.is_some());
        // the result carries the worker's CUMULATIVE build count, so the
        // per-worker figure is a max-fold, not a sum
        w.pipelines_built = w.pipelines_built.max(r.pipelines_built);
        w.metrics.merge(&r.metrics);
    }

    /// Fold one shard completely, collecting its outputs.
    pub fn add(&mut self, mut r: ShardResult<T>) {
        self.add_stats(&r);
        self.outputs.append(&mut r.outputs);
    }

    /// Finish into a report. `outputs` holds whatever [`ReportBuilder::add`]
    /// collected (empty for sink-consumed streaming runs).
    pub fn finish(self, elapsed: f64) -> ExecReport<T> {
        let per_worker: Vec<WorkerStats> = self.per_worker.into_values().collect();
        let pipelines_built = per_worker.iter().map(|w| w.pipelines_built).sum();
        // results arrive in stream order on both paths, but sort anyway
        // so the fault ledger is deterministic however it was fed
        let mut faults = self.faults;
        faults.sort_by_key(|f| f.shard);
        ExecReport {
            outputs: self.outputs,
            metrics: self.metrics,
            invocations: self.invocations,
            shards: self.shards,
            steals: self.steals,
            pipelines_built,
            retries: self.retries,
            faults,
            // filled by the runner from the RegionFolder's ledger on
            // split runs; unsplit regions are all-or-nothing
            partial_regions: Vec::new(),
            rerun_regions: self.rerun_regions,
            // overwritten by the runner on split runs; plain runs never
            // cut a region
            split_regions: 0,
            elapsed,
            per_worker,
            trace: None,
            metrics_report: None,
        }
    }
}

/// Fold shard results (already in shard order) into an [`ExecReport`].
pub fn merge_results<T>(results: Vec<ShardResult<T>>, elapsed: f64) -> ExecReport<T> {
    let mut b = ReportBuilder::new();
    for r in results {
        b.add(r);
    }
    b.finish(elapsed)
}

/// Re-folds a split region's part rows into one row before stream-order
/// emission — the merge half of intra-region parallelism
/// ([`crate::exec::split`]).
///
/// Fed shard results **in stream order** (the materialized join's
/// sorted results, or the ordered stream the [`StreamMerger`] emits),
/// it drains one [`SubShard`](super::split::SubShard) identity per
/// output row from the shared [`SplitQueue`](super::split::SplitQueue)
/// and folds left-linear in part order: part 0 seeds the accumulator,
/// each later part folds via the factory's
/// [`combine`](super::factory::PipelineFactory::combine), the last part
/// emits. The fold shape is a pure function of part identity — which
/// worker ran which part, and in what completion order, cannot affect
/// the result.
///
/// A quarantined shard names its lost parts ([`ShardResult::lost`]);
/// the folder turns every region touched by a loss into a
/// [`PartialRegion`] ledger entry — the lost part indices plus the
/// folded value of each maximal contiguous surviving run — and emits
/// **no** output row for it, rather than a partial aggregate
/// masquerading as a total. Salvage is explicit: callers opt in by
/// reading the ledger ([`RegionFolder::take_partials`]).
pub struct RegionFolder<T> {
    queue: SharedSplitQueue,
    // Current contiguous surviving run: accumulator + the part index
    // that seeded it.
    acc: Option<T>,
    run_start: u32,
    // The current region's loss state (both empty while it is healthy).
    lost: Vec<u32>,
    salvaged: Vec<(u32, T)>,
    // Finished ledger entries, in region order.
    partials: Vec<PartialRegion<T>>,
}

impl<T> RegionFolder<T> {
    /// A folder draining part identities from `queue`.
    pub fn new(queue: SharedSplitQueue) -> RegionFolder<T> {
        RegionFolder {
            queue,
            acc: None,
            run_start: 0,
            lost: Vec::new(),
            salvaged: Vec::new(),
            partials: Vec::new(),
        }
    }

    /// Fold one shard's rows in place: `r.outputs` is rewritten to hold
    /// only the rows of regions this shard **completes** (a region's
    /// trailing parts may live in a later shard, whose fold will emit
    /// it). Healthy shards must produce exactly one row per part —
    /// that's what `Splittability::RegionFold` promises — and violations
    /// are named errors, not silent misalignment. Quarantined shards
    /// must produce one row per *surviving* part (`r.lost` names the
    /// dropped in-shard ordinals, ascending).
    pub fn fold_shard<F>(&mut self, factory: &F, r: &mut ShardResult<T>) -> Result<()>
    where
        F: PipelineFactory<Out = T>,
    {
        // A legacy whole-shard quarantine (no part list) loses every
        // part the shard covered.
        let all_lost: Vec<u32>;
        let lost_parts: &[u32] = if r.fault.is_some() && r.lost.is_empty() {
            all_lost = (0..r.regions as u32).collect();
            &all_lost
        } else {
            &r.lost
        };
        ensure!(
            r.outputs.len() + lost_parts.len() == r.regions,
            "region fold requires exactly one output row per surviving part, but \
             shard {} produced {} rows over {} parts ({} lost) — only \
             one-row-per-region stages may advertise Splittability::RegionFold",
            r.shard,
            r.outputs.len(),
            r.regions,
            lost_parts.len()
        );
        let mut queue = self.queue.borrow_mut();
        let mut rows = std::mem::take(&mut r.outputs).into_iter();
        let mut folded = Vec::with_capacity(rows.len());
        let mut lost_iter = lost_parts.iter().copied().peekable();
        for ordinal in 0..r.regions as u32 {
            let sub = queue.pop().ok_or_else(|| {
                anyhow::anyhow!("region fold: split queue ran dry mid-stream (executor bug)")
            })?;
            if lost_iter.peek() == Some(&ordinal) {
                lost_iter.next();
                // a lost part closes the current surviving run
                if let Some(v) = self.acc.take() {
                    self.salvaged.push((self.run_start, v));
                }
                self.lost.push(sub.part);
            } else {
                let row = rows.next().expect("row count ensured above");
                if let Some(acc) = self.acc.as_mut() {
                    // previous part of this region survived: extend the run
                    factory.combine(acc, row)?;
                } else {
                    ensure!(
                        sub.part == 0 || !self.lost.is_empty(),
                        "region fold: part {} of region {} arrived with no accumulator \
                         (executor bug)",
                        sub.part,
                        sub.region
                    );
                    self.acc = Some(row);
                    self.run_start = sub.part;
                }
            }
            if sub.is_last() {
                if self.lost.is_empty() {
                    let done = self.acc.take().ok_or_else(|| {
                        anyhow::anyhow!(
                            "region fold: region {} closed with no accumulator (executor bug)",
                            sub.region
                        )
                    })?;
                    folded.push(done);
                } else {
                    if let Some(v) = self.acc.take() {
                        self.salvaged.push((self.run_start, v));
                    }
                    self.partials.push(PartialRegion {
                        region: sub.region,
                        of: sub.of,
                        lost: std::mem::take(&mut self.lost),
                        salvaged: std::mem::take(&mut self.salvaged),
                    });
                }
            }
        }
        r.outputs = folded;
        Ok(())
    }

    /// Drain the salvage ledger accumulated so far (regions with lost
    /// parts, in region order). The runner folds this into
    /// [`ExecReport::partial_regions`].
    pub fn take_partials(&mut self) -> Vec<PartialRegion<T>> {
        std::mem::take(&mut self.partials)
    }

    /// Assert every part identity was consumed and no region is left
    /// half-folded — called once after the last shard.
    pub fn finish(&self) -> Result<()> {
        ensure!(
            self.queue.borrow().pending() == 0,
            "region fold: {} part identities were never matched to output rows \
             (executor bug)",
            self.queue.borrow().pending()
        );
        ensure!(
            self.acc.is_none() && self.lost.is_empty() && self.salvaged.is_empty(),
            "region fold: the stream ended mid-region (executor bug)"
        );
        Ok(())
    }
}

/// Order-restoring window for streaming runs: shard results arrive in
/// completion order, leave in stream order, as soon as the contiguous
/// prefix is complete.
///
/// Backed by a ring of `capacity` pre-allocated slots — enough for every
/// shard the ingest budget allows in flight, so accepting and releasing
/// results allocates nothing per shard. Indices outside the window
/// (`[next_expected, next_expected + capacity)`) are executor bugs and
/// reported as errors, not silently buffered.
#[derive(Debug)]
pub struct StreamMerger<T> {
    slots: Vec<Option<ShardResult<T>>>,
    next: usize,
    hub: MetricsHub,
}

impl<T> StreamMerger<T> {
    /// Create a merger with `capacity` in-flight slots.
    pub fn with_capacity(capacity: usize) -> StreamMerger<T> {
        StreamMerger {
            slots: (0..capacity.max(1)).map(|_| None).collect(),
            next: 0,
            hub: MetricsHub::disabled(),
        }
    }

    /// Attach the driver's metrics lane: each in-order release then
    /// stamps its emit time and records one end-to-end latency sample
    /// per region of the released shard (emit − submit, both against the
    /// run's shared epoch). A disabled hub (the default) costs one
    /// branch per release and reads no clock.
    pub fn with_hub(mut self, hub: MetricsHub) -> StreamMerger<T> {
        self.hub = hub;
        self
    }

    /// Accept one completed shard result (any completion order).
    pub fn accept(&mut self, r: ShardResult<T>) -> Result<()> {
        let cap = self.slots.len();
        ensure!(
            r.shard >= self.next && r.shard < self.next + cap,
            "stream merger: shard {} outside the reassembly window [{}, {})",
            r.shard,
            self.next,
            self.next + cap
        );
        let slot = &mut self.slots[r.shard % cap];
        ensure!(slot.is_none(), "stream merger: duplicate shard {}", r.shard);
        *slot = Some(r);
        Ok(())
    }

    /// Release the next in-order result, if it has arrived. With a
    /// metrics hub attached, the release is the stream slot's emit
    /// stamp: end-to-end latency is recorded here, once per region.
    pub fn pop_ready(&mut self) -> Option<ShardResult<T>> {
        let cap = self.slots.len();
        let r = self.slots[self.next % cap].take()?;
        self.next += 1;
        if self.hub.enabled() {
            let e2e = self.hub.now_ns().saturating_sub(r.submit_ns);
            self.hub.record_emit(r.regions as u64, e2e);
        }
        Some(r)
    }

    /// The shard index the stream is waiting on.
    pub fn next_expected(&self) -> usize {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::NodeMetrics;

    fn shard(shard: usize, worker: usize, outputs: Vec<i32>, items: usize) -> ShardResult<i32> {
        let mut nm = NodeMetrics::new(4);
        for _ in 0..items {
            nm.record_ensemble(2);
        }
        let metrics = PipelineMetrics {
            nodes: vec![("n".to_string(), nm)],
            elapsed: 0.25,
            idle_polls: 1,
        };
        ShardResult {
            shard,
            worker,
            regions: outputs.len(),
            stolen: worker == 1,
            outputs,
            metrics,
            invocations: items as u64,
            elapsed: 0.5,
            pipelines_built: 1,
            retries: 0,
            fault: None,
            lost: Vec::new(),
            rerun_regions: 0,
            submit_ns: 0,
        }
    }

    #[test]
    fn outputs_concatenate_in_shard_order() {
        let report = merge_results(
            vec![
                shard(0, 1, vec![1, 2], 2),
                shard(1, 0, vec![3], 1),
                shard(2, 1, vec![4, 5], 2),
            ],
            2.0,
        );
        assert_eq!(report.outputs, vec![1, 2, 3, 4, 5]);
        assert_eq!(report.shards, 3);
        assert_eq!(report.invocations, 5);
        assert_eq!(report.steals, 2, "worker 1's shards are marked stolen");
        assert_eq!(report.metrics.node("n").unwrap().ensembles, 5);
    }

    #[test]
    fn per_worker_breakdown_aggregates() {
        let report = merge_results(
            vec![
                shard(0, 1, vec![1, 2], 2),
                shard(1, 0, vec![3], 1),
                shard(2, 1, vec![4, 5], 2),
            ],
            2.0,
        );
        assert_eq!(report.per_worker.len(), 2);
        assert_eq!(report.per_worker[0].worker, 0);
        assert_eq!(report.per_worker[0].shards, 1);
        assert_eq!(report.per_worker[0].steals, 0);
        assert_eq!(report.per_worker[1].worker, 1);
        assert_eq!(report.per_worker[1].shards, 2);
        assert_eq!(report.per_worker[1].steals, 2);
        assert_eq!(report.per_worker[1].outputs, 4);
        assert!((report.per_worker[1].busy - 1.0).abs() < 1e-12);
        let table = report.worker_table();
        assert!(table.contains("worker"), "{table}");
        assert!(table.contains("stolen"), "{table}");
        assert!(table.contains("built"), "{table}");
        assert!(table.contains("occ%"), "{table}");
        assert!(table.contains("idle%"), "{table}");
        // worker 1: busy 1.0 of wall 2.0 → 50% idle
        assert!(table.contains(" 50.0\n"), "{table}");
        assert!(report.utilization() > 0.0);
    }

    #[test]
    fn pipeline_builds_fold_per_worker_not_per_shard() {
        // worker 1 ran two shards on ONE persistent pipeline (cumulative
        // build count 1 on both results); worker 0 ran one shard. The
        // report must show builds == workers (2), not shards (3).
        let report = merge_results(
            vec![
                shard(0, 1, vec![1, 2], 2),
                shard(1, 0, vec![3], 1),
                shard(2, 1, vec![4, 5], 2),
            ],
            2.0,
        );
        assert_eq!(report.shards, 3);
        assert_eq!(report.pipelines_built, 2);
        assert_eq!(report.per_worker[0].pipelines_built, 1);
        assert_eq!(report.per_worker[1].pipelines_built, 1);

        // a worker that rebuilt per shard reports a growing cumulative
        // count; the max-fold surfaces the rebuild instead of hiding it
        let mut rebuilt = vec![shard(0, 0, vec![1], 1), shard(1, 0, vec![2], 1)];
        rebuilt[1].pipelines_built = 2;
        let report = merge_results(rebuilt, 1.0);
        assert_eq!(report.pipelines_built, 2, "rebuild must be visible");
        assert_eq!(report.per_worker[0].pipelines_built, 2);
    }

    #[test]
    fn retries_and_quarantines_fold_into_the_report() {
        let mut results = vec![
            shard(0, 0, vec![1, 2], 2),
            shard(1, 1, vec![], 0),
            shard(2, 0, vec![3], 1),
        ];
        // shard 0 recovered after 2 retries; shard 1 was quarantined
        results[0].retries = 2;
        results[0].pipelines_built = 3;
        results[1].fault = Some("injected fault: shard 1 panics on worker 1".to_string());
        let report = merge_results(results, 2.0);
        assert_eq!(report.retries, 2);
        assert_eq!(report.faults.len(), 1);
        let f = &report.faults[0];
        assert_eq!((f.shard, f.worker, f.attempts), (1, 1, 1));
        assert!(f.error.contains("injected fault"), "{}", f.error);
        assert_eq!(report.per_worker[0].retries, 2);
        assert_eq!(report.per_worker[0].faults, 0);
        assert_eq!(report.per_worker[1].retries, 0);
        assert_eq!(report.per_worker[1].faults, 1);
        // the recovery rebuilds stay visible in the build count
        assert_eq!(report.per_worker[0].pipelines_built, 3);
        let table = report.worker_table();
        assert!(table.contains("retry"), "{table}");
        assert!(table.contains("fault"), "{table}");
        let faults = report.fault_table();
        assert!(faults.contains("shard"), "{faults}");
        assert!(faults.contains("injected fault"), "{faults}");
        // fault-free runs render an empty ledger
        assert_eq!(merge_results(vec![shard(0, 0, vec![1], 1)], 1.0).fault_table(), "");
    }

    #[test]
    fn empty_merge_is_empty_report() {
        let report = merge_results(Vec::<ShardResult<i32>>::new(), 0.1);
        assert!(report.outputs.is_empty());
        assert_eq!(report.shards, 0);
        assert!(report.per_worker.is_empty());
        assert_eq!(report.utilization(), 0.0);
    }

    #[test]
    fn stream_merger_reorders_within_the_window() {
        let mut m: StreamMerger<i32> = StreamMerger::with_capacity(4);
        assert!(m.pop_ready().is_none());
        m.accept(shard(2, 0, vec![30], 1)).unwrap();
        m.accept(shard(0, 0, vec![10], 1)).unwrap();
        assert_eq!(m.pop_ready().unwrap().shard, 0);
        assert!(m.pop_ready().is_none(), "shard 1 still missing");
        m.accept(shard(1, 0, vec![20], 1)).unwrap();
        assert_eq!(m.pop_ready().unwrap().shard, 1);
        assert_eq!(m.pop_ready().unwrap().shard, 2);
        assert!(m.pop_ready().is_none());
        assert_eq!(m.next_expected(), 3);
        // the window slid: shard 5 is now acceptable, 7 is not
        m.accept(shard(5, 0, vec![50], 1)).unwrap();
        let err = m.accept(shard(7, 0, vec![70], 1)).unwrap_err();
        assert!(err.to_string().contains("window"), "{err}");
    }

    #[test]
    fn stream_merger_stamps_emit_latency_per_region() {
        let hub = crate::metrics::MetricsSpec::new().hub();
        let mut m: StreamMerger<i32> = StreamMerger::with_capacity(2).with_hub(hub.clone());
        let mut r = shard(0, 0, vec![1, 2], 2);
        r.submit_ns = hub.now_ns();
        m.accept(r).unwrap();
        assert!(m.pop_ready().is_some());
        let lane = hub.take();
        assert_eq!(lane.emitted_shards, 1);
        assert_eq!(lane.emitted_regions, 2);
        assert_eq!(lane.e2e.count, 2, "one end-to-end sample per region");
    }

    #[test]
    fn stream_merger_rejects_duplicates() {
        let mut m: StreamMerger<i32> = StreamMerger::with_capacity(2);
        m.accept(shard(0, 0, vec![1], 1)).unwrap();
        let err = m.accept(shard(0, 0, vec![1], 1)).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    mod region_fold {
        use super::super::*;
        use super::shard;
        use crate::exec::factory::{ShardOutput, ShardWorker, Splittability};
        use crate::exec::split::SplitQueue;
        use std::cell::RefCell;
        use std::rc::Rc;

        /// Fold-only toy: combine sums rows; the worker is never run.
        struct FoldFactory;
        struct NopWorker;
        impl ShardWorker for NopWorker {
            type In = ();
            type Out = i32;
            fn run_shard(&mut self, _shard: &[()]) -> Result<ShardOutput<i32>> {
                unreachable!("folder tests never execute shards")
            }
        }
        impl PipelineFactory for FoldFactory {
            type In = ();
            type Out = i32;
            type Worker = NopWorker;
            fn make_worker(&self, _worker_id: usize) -> Result<NopWorker> {
                Ok(NopWorker)
            }
            fn splittability(&self) -> Splittability {
                Splittability::RegionFold
            }
            fn combine(&self, acc: &mut i32, part: i32) -> Result<()> {
                *acc += part;
                Ok(())
            }
        }

        fn queue_of(regions: &[u32]) -> SharedSplitQueue {
            let mut q = SplitQueue::new(true);
            for &of in regions {
                q.push_region(of);
            }
            Rc::new(RefCell::new(q))
        }

        #[test]
        fn folds_parts_left_linear_across_shard_boundaries() {
            // region 0 unsplit, region 1 in 3 parts straddling two
            // shards, region 2 unsplit
            let queue = queue_of(&[1, 3, 1]);
            let mut folder = RegionFolder::new(queue);
            let mut a = shard(0, 0, vec![10, 1, 2], 3); // r0 | r1 parts 0,1
            let mut b = shard(1, 1, vec![4, 20], 2); // r1 part 2 | r2
            folder.fold_shard(&FoldFactory, &mut a).unwrap();
            folder.fold_shard(&FoldFactory, &mut b).unwrap();
            assert_eq!(a.outputs, vec![10], "region 1 incomplete in shard 0");
            assert_eq!(b.outputs, vec![1 + 2 + 4, 20], "completed at part 2");
            folder.finish().unwrap();
        }

        #[test]
        fn quarantined_shard_salvages_surviving_parts_into_the_ledger() {
            // region 0: 2 parts, part 0 healthy, part 1 quarantined —
            // the region emits no total, but the ledger names the lost
            // part and salvages the surviving run
            let queue = queue_of(&[2, 1]);
            let mut folder = RegionFolder::new(queue);
            let mut a = shard(0, 0, vec![5], 1);
            let mut b = shard(1, 1, vec![], 1);
            b.regions = 1; // the helper derives regions from outputs
            b.fault = Some("injected".to_string());
            let mut c = shard(2, 0, vec![7], 1);
            folder.fold_shard(&FoldFactory, &mut a).unwrap();
            folder.fold_shard(&FoldFactory, &mut b).unwrap();
            folder.fold_shard(&FoldFactory, &mut c).unwrap();
            assert_eq!(a.outputs, Vec::<i32>::new());
            assert_eq!(b.outputs, Vec::<i32>::new());
            assert_eq!(c.outputs, vec![7], "later regions are untouched");
            let partials = folder.take_partials();
            assert_eq!(
                partials,
                vec![PartialRegion {
                    region: 0,
                    of: 2,
                    lost: vec![1],
                    salvaged: vec![(0, 5)],
                }]
            );
            folder.finish().unwrap();
        }

        #[test]
        fn part_granular_quarantine_salvages_around_the_lost_part() {
            // one shard covers region 0's 3 parts; only part 1 is lost
            // (part-granular quarantine) — both neighbours are salvaged
            // as separate runs because the fold is not commutative
            let queue = queue_of(&[3, 1]);
            let mut folder = RegionFolder::new(queue);
            let mut a = shard(0, 0, vec![5, 9], 2);
            a.regions = 3;
            a.fault = Some("injected".to_string());
            a.lost = vec![1];
            let mut c = shard(1, 0, vec![7], 1);
            folder.fold_shard(&FoldFactory, &mut a).unwrap();
            folder.fold_shard(&FoldFactory, &mut c).unwrap();
            assert_eq!(a.outputs, Vec::<i32>::new());
            assert_eq!(c.outputs, vec![7]);
            assert_eq!(
                folder.take_partials(),
                vec![PartialRegion {
                    region: 0,
                    of: 3,
                    lost: vec![1],
                    salvaged: vec![(0, 5), (2, 9)],
                }]
            );
            folder.finish().unwrap();
        }

        #[test]
        fn row_count_mismatch_is_a_named_error() {
            let queue = queue_of(&[2]);
            let mut folder = RegionFolder::new(queue);
            let mut bad = shard(0, 0, vec![1, 2, 3], 2);
            bad.regions = 2;
            let err = folder.fold_shard(&FoldFactory, &mut bad).unwrap_err();
            assert!(
                err.to_string().contains("exactly one output row per surviving part"),
                "{err}"
            );
        }

        #[test]
        fn finish_rejects_a_half_folded_region() {
            let queue = queue_of(&[2]);
            let mut folder = RegionFolder::new(queue);
            let mut a = shard(0, 0, vec![1], 1);
            folder.fold_shard(&FoldFactory, &mut a).unwrap();
            assert_eq!(a.outputs, Vec::<i32>::new(), "region still open");
            let err = folder.finish().unwrap_err();
            assert!(err.to_string().contains("never matched"), "{err}");
        }
    }

    #[test]
    fn streamed_stats_match_materialized_merge() {
        let results = vec![
            shard(0, 1, vec![1, 2], 2),
            shard(1, 0, vec![3], 1),
            shard(2, 1, vec![4, 5], 2),
        ];
        let want = merge_results(results.clone(), 2.0);
        let mut b = ReportBuilder::new();
        let mut sunk = Vec::new();
        for r in results {
            b.add_stats(&r);
            sunk.extend(r.outputs);
        }
        let got = b.finish(2.0);
        assert!(got.outputs.is_empty(), "sink consumed the outputs");
        assert_eq!(sunk, want.outputs);
        assert_eq!(got.shards, want.shards);
        assert_eq!(got.steals, want.steals);
        assert_eq!(got.invocations, want.invocations);
        assert_eq!(got.per_worker.len(), want.per_worker.len());
        for (g, w) in got.per_worker.iter().zip(&want.per_worker) {
            assert_eq!(g.shards, w.shards);
            assert_eq!(g.outputs, w.outputs);
        }
    }
}
