//! Deterministic merge: reassemble per-shard outputs in original stream
//! order and fold per-shard [`PipelineMetrics`] into one global report
//! with a per-worker breakdown.
//!
//! Because shards are contiguous ranges of the region stream and the pool
//! returns results sorted by shard index, concatenation *is* stream
//! order — the merge involves no reordering heuristics and is independent
//! of which worker ran what, or when. Metrics are folded in shard order
//! too, so the global counters are identical run to run.

use std::collections::BTreeMap;

use crate::coordinator::metrics::PipelineMetrics;

use super::pool::ShardResult;

/// Aggregated execution stats for one worker of a sharded run.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    /// Worker id (0-based).
    pub worker: usize,
    /// Shards this worker executed.
    pub shards: usize,
    /// Output items it produced.
    pub outputs: usize,
    /// Kernel invocations it spent.
    pub invocations: u64,
    /// Seconds spent actually running shards (its busy time).
    pub busy: f64,
    /// Its pipeline metrics, folded across its shards.
    pub metrics: PipelineMetrics,
}

/// The merged result of a sharded run.
#[derive(Debug, Clone)]
pub struct ExecReport<T> {
    /// All outputs, in original stream order.
    pub outputs: Vec<T>,
    /// Global pipeline metrics: every worker's counters folded together
    /// (`elapsed` is the max pipeline-internal time, as in
    /// [`PipelineMetrics::merge`]).
    pub metrics: PipelineMetrics,
    /// Total kernel invocations across workers.
    pub invocations: u64,
    /// Number of shards executed.
    pub shards: usize,
    /// Wall-clock seconds of the whole sharded run (plan + pool + merge).
    pub elapsed: f64,
    /// Per-worker breakdown, sorted by worker id (workers that never
    /// claimed a shard are absent).
    pub per_worker: Vec<WorkerStats>,
}

impl<T> ExecReport<T> {
    /// Parallel efficiency proxy: total busy time over (wall × workers
    /// observed). 1.0 = every worker busy the whole run.
    pub fn utilization(&self) -> f64 {
        if self.per_worker.is_empty() || self.elapsed <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.per_worker.iter().map(|w| w.busy).sum();
        busy / (self.elapsed * self.per_worker.len() as f64)
    }

    /// Render the per-worker breakdown (used by `--stats`).
    pub fn worker_table(&self) -> String {
        let mut out = String::from("worker   shards   outputs   kernel_inv   busy_s    occ%\n");
        for w in &self.per_worker {
            out.push_str(&format!(
                "{:<8} {:>6}  {:>8}  {:>11}  {:>7.3}  {:>5.1}\n",
                w.worker,
                w.shards,
                w.outputs,
                w.invocations,
                w.busy,
                100.0 * w.metrics.occupancy(),
            ));
        }
        out
    }
}

/// Fold shard results (already in shard order) into an [`ExecReport`].
pub fn merge_results<T>(results: Vec<ShardResult<T>>, elapsed: f64) -> ExecReport<T> {
    let shards = results.len();
    let mut outputs = Vec::with_capacity(results.iter().map(|r| r.outputs.len()).sum());
    let mut metrics = PipelineMetrics::default();
    let mut invocations = 0u64;
    let mut per_worker: BTreeMap<usize, WorkerStats> = BTreeMap::new();
    for r in results {
        let n_out = r.outputs.len();
        outputs.extend(r.outputs);
        metrics.merge(&r.metrics);
        invocations += r.invocations;
        let w = per_worker.entry(r.worker).or_insert_with(|| WorkerStats {
            worker: r.worker,
            shards: 0,
            outputs: 0,
            invocations: 0,
            busy: 0.0,
            metrics: PipelineMetrics::default(),
        });
        w.shards += 1;
        w.outputs += n_out;
        w.invocations += r.invocations;
        w.busy += r.elapsed;
        w.metrics.merge(&r.metrics);
    }
    ExecReport {
        outputs,
        metrics,
        invocations,
        shards,
        elapsed,
        per_worker: per_worker.into_values().collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::NodeMetrics;

    fn shard(shard: usize, worker: usize, outputs: Vec<i32>, items: usize) -> ShardResult<i32> {
        let mut nm = NodeMetrics::new(4);
        for _ in 0..items {
            nm.record_ensemble(2);
        }
        let metrics = PipelineMetrics {
            nodes: vec![("n".to_string(), nm)],
            elapsed: 0.25,
            idle_polls: 1,
        };
        ShardResult {
            shard,
            worker,
            outputs,
            metrics,
            invocations: items as u64,
            elapsed: 0.5,
        }
    }

    #[test]
    fn outputs_concatenate_in_shard_order() {
        let report = merge_results(
            vec![
                shard(0, 1, vec![1, 2], 2),
                shard(1, 0, vec![3], 1),
                shard(2, 1, vec![4, 5], 2),
            ],
            2.0,
        );
        assert_eq!(report.outputs, vec![1, 2, 3, 4, 5]);
        assert_eq!(report.shards, 3);
        assert_eq!(report.invocations, 5);
        assert_eq!(report.metrics.node("n").unwrap().ensembles, 5);
    }

    #[test]
    fn per_worker_breakdown_aggregates() {
        let report = merge_results(
            vec![
                shard(0, 1, vec![1, 2], 2),
                shard(1, 0, vec![3], 1),
                shard(2, 1, vec![4, 5], 2),
            ],
            2.0,
        );
        assert_eq!(report.per_worker.len(), 2);
        assert_eq!(report.per_worker[0].worker, 0);
        assert_eq!(report.per_worker[0].shards, 1);
        assert_eq!(report.per_worker[1].worker, 1);
        assert_eq!(report.per_worker[1].shards, 2);
        assert_eq!(report.per_worker[1].outputs, 4);
        assert!((report.per_worker[1].busy - 1.0).abs() < 1e-12);
        let table = report.worker_table();
        assert!(table.contains("worker"), "{table}");
        assert!(report.utilization() > 0.0);
    }

    #[test]
    fn empty_merge_is_empty_report() {
        let report = merge_results(Vec::<ShardResult<i32>>::new(), 0.1);
        assert!(report.outputs.is_empty());
        assert_eq!(report.shards, 0);
        assert!(report.per_worker.is_empty());
        assert_eq!(report.utilization(), 0.0);
    }
}
