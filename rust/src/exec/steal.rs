//! Per-worker work deques with LIFO-local / FIFO-steal claiming, plus the
//! completion buffer workers report through.
//!
//! This replaces the pool's original single atomic shard cursor. Each
//! worker owns a deque; new work is dealt round-robin across deques; a
//! worker pops its **own newest** item (LIFO — hot caches, and in
//! streaming mode the most recently ingested shard), and when its deque
//! is empty it steals the **oldest** item from another worker's deque
//! (FIFO — the shard that has waited longest, classic Arora/Blumofe/
//! Plaxton discipline). Stolen units are whole region-aligned shards,
//! never parts of one, so region-scoped state stays private to whichever
//! worker runs the shard (the state-access-pattern argument from
//! Danelutto et al.; see PAPERS.md).
//!
//! Shards are coarse (milliseconds, not nanoseconds), so a plain
//! mutex+condvar around all deques is the right tool: claims are rare,
//! contention is negligible, and blocked workers sleep instead of
//! spinning. The condvar matters only in streaming mode, where deques
//! refill as ingest proceeds; for materialized plans every deque is
//! loaded before the pool starts and `close` is called up front, so a
//! worker never waits.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::ingest::lock_ignore_poison;

/// How workers claim shards from the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClaimMode {
    /// Per-worker deques, LIFO-local pop, FIFO steal when empty.
    #[default]
    Steal,
    /// Per-worker deques without stealing (ablation: shows what stealing
    /// buys on skewed streams).
    NoSteal,
    /// The original single shared atomic cursor (materialized plans
    /// only; kept as the `bench ingest` baseline).
    Cursor,
}

impl ClaimMode {
    pub fn label(&self) -> &'static str {
        match self {
            ClaimMode::Steal => "steal",
            ClaimMode::NoSteal => "no-steal",
            ClaimMode::Cursor => "cursor",
        }
    }
}

/// What a claim returned.
pub enum Claim<W> {
    /// A unit of work, with `stolen = true` if it came off another
    /// worker's deque.
    Task { work: W, stolen: bool },
    /// The queues are closed and drained: no more work will ever come.
    Done,
}

struct QueuesInner<W> {
    deques: Vec<VecDeque<W>>,
    next_push: usize,
    closed: bool,
}

/// The deque set. `W` is the unit of claimable work: a shard index for
/// materialized plans, an owned [`ShardTask`](super::ingest::ShardTask)
/// for streaming ingest.
pub struct StealQueues<W> {
    inner: Mutex<QueuesInner<W>>,
    work_cv: Condvar,
    steal: bool,
}

impl<W> StealQueues<W> {
    /// `workers` empty deques. `steal = false` disables cross-deque
    /// claiming (the [`ClaimMode::NoSteal`] ablation).
    pub fn new(workers: usize, steal: bool) -> StealQueues<W> {
        StealQueues {
            inner: Mutex::new(QueuesInner {
                deques: (0..workers.max(1)).map(|_| VecDeque::new()).collect(),
                next_push: 0,
                closed: false,
            }),
            work_cv: Condvar::new(),
            steal,
        }
    }

    /// Deal one unit of work to the next deque round-robin and wake the
    /// sleepers. `notify_all`, not `notify_one`: in no-steal mode a
    /// single wakeup could land on a worker whose own deque is empty,
    /// stranding the task (and deadlocking a backpressured ingest
    /// driver) — shards are coarse, so the broadcast costs nothing.
    pub fn push(&self, work: W) {
        let mut q = lock_ignore_poison(&self.inner);
        let target = q.next_push;
        q.next_push = (q.next_push + 1) % q.deques.len();
        q.deques[target].push_back(work);
        drop(q);
        self.work_cv.notify_all();
    }

    /// No more work will arrive; wake everyone so idle workers can exit.
    pub fn close(&self) {
        lock_ignore_poison(&self.inner).closed = true;
        self.work_cv.notify_all();
    }

    /// Claim work for `worker`: own deque LIFO, then (if enabled) steal
    /// FIFO from the others, scanning round-robin from the next worker.
    /// Blocks while all deques are empty and the queues are still open.
    pub fn claim(&self, worker: usize) -> Claim<W> {
        let mut q = lock_ignore_poison(&self.inner);
        loop {
            if let Some(work) = q.deques[worker].pop_back() {
                return Claim::Task {
                    work,
                    stolen: false,
                };
            }
            if self.steal {
                let n = q.deques.len();
                for off in 1..n {
                    let victim = (worker + off) % n;
                    if let Some(work) = q.deques[victim].pop_front() {
                        return Claim::Task {
                            work,
                            stolen: true,
                        };
                    }
                }
            }
            if q.closed {
                return Claim::Done;
            }
            q = self
                .work_cv
                .wait(q)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Units currently queued across all deques.
    pub fn queued(&self) -> usize {
        lock_ignore_poison(&self.inner).deques.iter().map(VecDeque::len).sum()
    }
}

/// Where streaming workers report finished (or failed) shards; the ingest
/// driver drains it to merge, emit, and release budget.
pub struct CompletionBuffer<R> {
    inner: Mutex<CompletionInner<R>>,
    done_cv: Condvar,
}

struct CompletionInner<R> {
    ready: Vec<R>,
    failure: Option<anyhow::Error>,
}

impl<R> Default for CompletionBuffer<R> {
    fn default() -> Self {
        CompletionBuffer::new()
    }
}

impl<R> CompletionBuffer<R> {
    pub fn new() -> CompletionBuffer<R> {
        CompletionBuffer {
            inner: Mutex::new(CompletionInner {
                ready: Vec::new(),
                failure: None,
            }),
            done_cv: Condvar::new(),
        }
    }

    /// Report one finished shard (worker side).
    pub fn push(&self, result: R) {
        lock_ignore_poison(&self.inner).ready.push(result);
        self.done_cv.notify_all();
    }

    /// Report a failure (worker side). The first failure wins; the run
    /// aborts once the driver observes it.
    pub fn fail(&self, err: anyhow::Error) {
        let mut c = lock_ignore_poison(&self.inner);
        c.failure.get_or_insert(err);
        drop(c);
        self.done_cv.notify_all();
    }

    /// Has a failure been reported?
    pub fn failed(&self) -> bool {
        lock_ignore_poison(&self.inner).failure.is_some()
    }

    /// Move any ready results into `out` without blocking. Returns the
    /// recorded failure, if one has been reported (taking it).
    pub fn drain_into(&self, out: &mut Vec<R>) -> Option<anyhow::Error> {
        let mut c = lock_ignore_poison(&self.inner);
        out.append(&mut c.ready);
        c.failure.take()
    }

    /// Like [`CompletionBuffer::drain_into`], but blocks until at least
    /// one result (or a failure) is available.
    pub fn wait_drain_into(&self, out: &mut Vec<R>) -> Option<anyhow::Error> {
        let mut c = lock_ignore_poison(&self.inner);
        while c.ready.is_empty() && c.failure.is_none() {
            c = self
                .done_cv
                .wait(c)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        out.append(&mut c.ready);
        c.failure.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_claims(q: &StealQueues<u32>, worker: usize) -> Vec<(u32, bool)> {
        let mut got = Vec::new();
        loop {
            match q.claim(worker) {
                Claim::Task { work, stolen } => got.push((work, stolen)),
                Claim::Done => return got,
            }
        }
    }

    #[test]
    fn own_deque_pops_lifo() {
        let q: StealQueues<u32> = StealQueues::new(2, true);
        // round-robin: 0,2,4 → worker 0; 1,3 → worker 1
        for i in 0..5 {
            q.push(i);
        }
        q.close();
        let got = drain_claims(&q, 0);
        let own: Vec<u32> = got.iter().filter(|(_, s)| !s).map(|&(w, _)| w).collect();
        assert_eq!(own, vec![4, 2, 0], "own deque is LIFO");
    }

    #[test]
    fn steals_come_fifo_from_victims() {
        let q: StealQueues<u32> = StealQueues::new(2, true);
        for i in 0..6 {
            q.push(i); // worker 0 gets 0,2,4; worker 1 gets 1,3,5
        }
        q.close();
        // worker 1 drains everything: its own LIFO first, then steals
        // worker 0's deque front-first
        let got = drain_claims(&q, 1);
        assert_eq!(
            got,
            vec![
                (5, false),
                (3, false),
                (1, false),
                (0, true),
                (2, true),
                (4, true)
            ]
        );
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn no_steal_mode_leaves_other_deques_alone() {
        let q: StealQueues<u32> = StealQueues::new(2, false);
        for i in 0..4 {
            q.push(i);
        }
        q.close();
        assert_eq!(drain_claims(&q, 1), vec![(3, false), (1, false)]);
        assert_eq!(q.queued(), 2, "worker 0's work is untouched");
    }

    #[test]
    fn blocked_claim_wakes_on_push_and_close() {
        let q: StealQueues<u32> = StealQueues::new(1, true);
        std::thread::scope(|s| {
            let h = s.spawn(|| drain_claims(&q, 0));
            // give the claimer a moment to block, then feed + close
            std::thread::sleep(std::time::Duration::from_millis(10));
            q.push(7);
            std::thread::sleep(std::time::Duration::from_millis(10));
            q.close();
            assert_eq!(h.join().unwrap(), vec![(7, false)]);
        });
    }

    #[test]
    fn completion_buffer_delivers_results_then_failure() {
        let c: CompletionBuffer<u32> = CompletionBuffer::new();
        let mut out = Vec::new();
        assert!(c.drain_into(&mut out).is_none());
        c.push(1);
        c.push(2);
        assert!(c.wait_drain_into(&mut out).is_none());
        assert_eq!(out, vec![1, 2]);
        c.fail(anyhow::anyhow!("boom"));
        c.fail(anyhow::anyhow!("second, ignored"));
        assert!(c.failed());
        let err = c.drain_into(&mut out).expect("failure surfaces");
        assert_eq!(err.to_string(), "boom");
        assert!(c.drain_into(&mut out).is_none(), "failure is taken once");
    }
}
