//! Per-worker work deques with LIFO-local / FIFO-steal claiming, plus the
//! completion buffer workers report through.
//!
//! This replaces the pool's original single atomic shard cursor. Each
//! worker owns a deque; new work is dealt round-robin across deques; a
//! worker pops its **own newest** item (LIFO — hot caches, and in
//! streaming mode the most recently ingested shard), and when its deque
//! is empty it steals the **oldest** item from another worker's deque
//! (FIFO — the shard that has waited longest, classic Arora/Blumofe/
//! Plaxton discipline). Stolen units are whole region-aligned shards,
//! never parts of one, so region-scoped state stays private to whichever
//! worker runs the shard (the state-access-pattern argument from
//! Danelutto et al.; see PAPERS.md).
//!
//! Shards are coarse (milliseconds, not nanoseconds), so a plain
//! mutex+condvar around all deques is the right tool: claims are rare,
//! contention is negligible, and blocked workers sleep instead of
//! spinning. The condvar matters only in streaming mode, where deques
//! refill as ingest proceeds; for materialized plans every deque is
//! loaded before the pool starts and `close` is called up front, so a
//! worker never waits.
//!
//! **No wait in this module is unbounded.** Every blocking claim or
//! drain takes a watchdog deadline and shares a [`Pulse`] — a global
//! progress heartbeat beaten by pushes, successful claims, completions
//! and failures. A wait only fails once a full deadline passes with no
//! beat anywhere in the pool, so a worker idling while a sibling churns
//! through a heavy shard is not a stall; a lost wake-up or a
//! never-completing shard turns into a named error instead of a hang.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::ingest::lock_ignore_poison;

/// Global progress heartbeat for the pool's watchdog. Shared (one per
/// run) between [`StealQueues`] and [`CompletionBuffer`]: any push,
/// successful claim, completion or failure beats it, and watchdog waits
/// reset their deadline whenever the count advances — so the watchdog
/// measures *pool-wide* inactivity, not one worker's idleness.
#[derive(Debug, Default)]
pub struct Pulse {
    beats: AtomicU64,
}

impl Pulse {
    /// Record one unit of pool progress.
    pub fn beat(&self) {
        self.beats.fetch_add(1, Ordering::Relaxed);
    }

    /// Beats so far (watchdog waits compare snapshots of this).
    pub fn count(&self) -> u64 {
        self.beats.load(Ordering::Relaxed)
    }
}

/// How workers claim shards from the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClaimMode {
    /// Per-worker deques, LIFO-local pop, FIFO steal when empty.
    #[default]
    Steal,
    /// Per-worker deques without stealing (ablation: shows what stealing
    /// buys on skewed streams).
    NoSteal,
    /// The original single shared atomic cursor (materialized plans
    /// only; kept as the `bench ingest` baseline).
    Cursor,
}

impl ClaimMode {
    /// Short name used in tables and JSON output.
    pub fn label(&self) -> &'static str {
        match self {
            ClaimMode::Steal => "steal",
            ClaimMode::NoSteal => "no-steal",
            ClaimMode::Cursor => "cursor",
        }
    }
}

/// What a claim returned.
pub enum Claim<W> {
    /// A unit of work, with `stolen = true` if it came off another
    /// worker's deque.
    Task {
        /// The claimed unit of work.
        work: W,
        /// Did it come off another worker's deque?
        stolen: bool,
        /// Time this claim spent blocked waiting for work to appear
        /// (zero when work was immediately available — the fast path
        /// reads no clock). Feeds per-worker idle accounting in the
        /// metrics layer.
        waited: Duration,
    },
    /// The queues are closed and drained: no more work will ever come.
    Done,
}

struct QueuesInner<W> {
    deques: Vec<VecDeque<W>>,
    next_push: usize,
    closed: bool,
    // Workers still inside their claim loop. Decremented under this
    // same lock the moment a claim observes `Done` (or a worker
    // retires), so a retiring worker can tell — race-free — whether any
    // surviving sibling will ever look at the deques again.
    live: usize,
}

/// The deque set. `W` is the unit of claimable work: a shard index for
/// materialized plans, an owned [`ShardTask`](super::ingest::ShardTask)
/// for streaming ingest.
pub struct StealQueues<W> {
    inner: Mutex<QueuesInner<W>>,
    work_cv: Condvar,
    steal: bool,
    pulse: Arc<Pulse>,
}

impl<W> StealQueues<W> {
    /// `workers` empty deques. `steal = false` disables cross-deque
    /// claiming (the [`ClaimMode::NoSteal`] ablation).
    pub fn new(workers: usize, steal: bool) -> StealQueues<W> {
        StealQueues {
            inner: Mutex::new(QueuesInner {
                deques: (0..workers.max(1)).map(|_| VecDeque::new()).collect(),
                next_push: 0,
                closed: false,
                live: workers.max(1),
            }),
            work_cv: Condvar::new(),
            steal,
            pulse: Arc::new(Pulse::default()),
        }
    }

    /// The queues' progress heartbeat — hand a clone to the
    /// [`CompletionBuffer`] (via
    /// [`CompletionBuffer::with_pulse`]) so completions defer the claim
    /// watchdog too.
    pub fn pulse(&self) -> Arc<Pulse> {
        self.pulse.clone()
    }

    /// Beat the queues' pulse without touching the deques — the ingest
    /// driver calls this per region pulled, so a slow (but live) source
    /// doesn't starve worker claim watchdogs into firing.
    pub fn beat(&self) {
        self.pulse.beat();
    }

    /// Deal one unit of work to the next deque round-robin and wake the
    /// sleepers. `notify_all`, not `notify_one`: in no-steal mode a
    /// single wakeup could land on a worker whose own deque is empty,
    /// stranding the task (and deadlocking a backpressured ingest
    /// driver) — shards are coarse, so the broadcast costs nothing.
    pub fn push(&self, work: W) {
        let mut q = lock_ignore_poison(&self.inner);
        let target = q.next_push;
        q.next_push = (q.next_push + 1) % q.deques.len();
        q.deques[target].push_back(work);
        drop(q);
        self.pulse.beat();
        self.work_cv.notify_all();
    }

    /// No more work will arrive; wake everyone so idle workers can exit.
    pub fn close(&self) {
        lock_ignore_poison(&self.inner).closed = true;
        self.pulse.beat();
        self.work_cv.notify_all();
    }

    /// Claim work for `worker`: own deque LIFO, then (if enabled) steal
    /// FIFO from the others, scanning round-robin from the next worker.
    /// Blocks while all deques are empty and the queues are still open —
    /// but never unboundedly: once `deadline` passes with no pool
    /// progress (no [`Pulse`] beat from any push, claim or completion),
    /// the wait fails with a named watchdog error instead of hanging.
    pub fn claim(&self, worker: usize, deadline: Duration) -> Result<Claim<W>> {
        let mut q = lock_ignore_poison(&self.inner);
        let mut seen = self.pulse.count();
        let mut last_progress = Instant::now();
        let mut waited = Duration::ZERO;
        loop {
            if let Some(work) = q.deques[worker].pop_back() {
                self.pulse.beat();
                return Ok(Claim::Task {
                    work,
                    stolen: false,
                    waited,
                });
            }
            if self.steal {
                let n = q.deques.len();
                for off in 1..n {
                    let victim = (worker + off) % n;
                    if let Some(work) = q.deques[victim].pop_front() {
                        self.pulse.beat();
                        return Ok(Claim::Task {
                            work,
                            stolen: true,
                            waited,
                        });
                    }
                }
            }
            if q.closed {
                // Leaving the claim loop for good: deregister under the
                // lock, so retirement hand-offs never target a worker
                // that has already decided to exit.
                q.live = q.live.saturating_sub(1);
                return Ok(Claim::Done);
            }
            let beats = self.pulse.count();
            if beats != seen {
                seen = beats;
                last_progress = Instant::now();
            }
            let remaining = deadline.saturating_sub(last_progress.elapsed());
            if remaining.is_zero() {
                let queued: usize = q.deques.iter().map(VecDeque::len).sum();
                bail!(
                    "stall watchdog: worker {worker} found no work and saw no pool \
                     progress for {deadline:?} ({queued} task(s) queued, queues still \
                     open) — a stuck shard or lost wake-up is holding the pool; raise \
                     the watchdog deadline if shards legitimately run longer"
                );
            }
            let wait_t0 = Instant::now();
            q = self
                .work_cv
                .wait_timeout(q, remaining)
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .0;
            waited += wait_t0.elapsed();
        }
    }

    /// Units currently queued across all deques.
    pub fn queued(&self) -> usize {
        lock_ignore_poison(&self.inner).deques.iter().map(VecDeque::len).sum()
    }

    /// Whether cross-deque stealing is enabled — a retiring worker may
    /// only hand its work back when a sibling can actually reach it.
    pub fn steals_enabled(&self) -> bool {
        self.steal
    }

    /// Number of worker deques.
    pub fn workers(&self) -> usize {
        lock_ignore_poison(&self.inner).deques.len()
    }

    /// A retiring worker hands its unfinished unit back to the pool.
    /// Atomically deregisters the caller from the live set and, **iff**
    /// at least one surviving sibling is still in its claim loop (and
    /// stealing is enabled, so the sibling can reach any deque), pushes
    /// the unit and wakes the sleepers — even after [`close`]: claims
    /// check the deques before the closed flag, so handed-back work is
    /// always drained before `Done`. Returns `false` when no survivor
    /// can ever claim the unit (no-steal mode, pool of one, or everyone
    /// else already exited) — the caller must abort by name instead of
    /// stranding the work.
    ///
    /// [`close`]: StealQueues::close
    pub fn push_for_retirement(&self, work: W) -> bool {
        let mut q = lock_ignore_poison(&self.inner);
        q.live = q.live.saturating_sub(1);
        if !self.steal || q.live == 0 {
            return false;
        }
        let target = q.next_push;
        q.next_push = (q.next_push + 1) % q.deques.len();
        q.deques[target].push_back(work);
        drop(q);
        self.pulse.beat();
        self.work_cv.notify_all();
        true
    }
}

/// Where streaming workers report finished (or failed) shards; the ingest
/// driver drains it to merge, emit, and release budget.
pub struct CompletionBuffer<R> {
    inner: Mutex<CompletionInner<R>>,
    done_cv: Condvar,
    pulse: Arc<Pulse>,
}

struct CompletionInner<R> {
    ready: Vec<R>,
    failure: Option<anyhow::Error>,
}

impl<R> Default for CompletionBuffer<R> {
    fn default() -> Self {
        CompletionBuffer::new()
    }
}

impl<R> CompletionBuffer<R> {
    /// Create an empty buffer.
    pub fn new() -> CompletionBuffer<R> {
        CompletionBuffer {
            inner: Mutex::new(CompletionInner {
                ready: Vec::new(),
                failure: None,
            }),
            done_cv: Condvar::new(),
            pulse: Arc::new(Pulse::default()),
        }
    }

    /// Share a [`Pulse`] with the run's [`StealQueues`], so completions
    /// and queue activity defer each other's watchdogs.
    pub fn with_pulse(mut self, pulse: Arc<Pulse>) -> CompletionBuffer<R> {
        self.pulse = pulse;
        self
    }

    /// Report one finished shard (worker side).
    pub fn push(&self, result: R) {
        lock_ignore_poison(&self.inner).ready.push(result);
        self.pulse.beat();
        self.done_cv.notify_all();
    }

    /// Report a failure (worker side). The first failure wins; the run
    /// aborts once the driver observes it.
    pub fn fail(&self, err: anyhow::Error) {
        let mut c = lock_ignore_poison(&self.inner);
        c.failure.get_or_insert(err);
        drop(c);
        self.pulse.beat();
        self.done_cv.notify_all();
    }

    /// Has a failure been reported?
    pub fn failed(&self) -> bool {
        lock_ignore_poison(&self.inner).failure.is_some()
    }

    /// Move any ready results into `out` without blocking. Returns the
    /// recorded failure, if one has been reported (taking it).
    pub fn drain_into(&self, out: &mut Vec<R>) -> Option<anyhow::Error> {
        let mut c = lock_ignore_poison(&self.inner);
        out.append(&mut c.ready);
        c.failure.take()
    }

    /// Like [`CompletionBuffer::drain_into`], but blocks until at least
    /// one result (or a failure) is available — bounded by the watchdog:
    /// once `deadline` passes with no pool progress (no [`Pulse`] beat),
    /// returns a named error instead of hanging. The caller (the ingest
    /// driver) adds the in-flight shard diagnostics it alone knows.
    pub fn wait_drain_into(
        &self,
        out: &mut Vec<R>,
        deadline: Duration,
    ) -> Result<Option<anyhow::Error>> {
        let mut c = lock_ignore_poison(&self.inner);
        let mut seen = self.pulse.count();
        let mut last_progress = Instant::now();
        loop {
            if !c.ready.is_empty() || c.failure.is_some() {
                out.append(&mut c.ready);
                return Ok(c.failure.take());
            }
            let beats = self.pulse.count();
            if beats != seen {
                seen = beats;
                last_progress = Instant::now();
            }
            let remaining = deadline.saturating_sub(last_progress.elapsed());
            if remaining.is_zero() {
                bail!(
                    "stall watchdog: no shard completed and no worker made progress \
                     for {deadline:?}"
                );
            }
            c = self
                .done_cv
                .wait_timeout(c, remaining)
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Generous deadline for tests that must never fire the watchdog.
    const CALM: Duration = Duration::from_secs(10);

    fn drain_claims(q: &StealQueues<u32>, worker: usize) -> Vec<(u32, bool)> {
        let mut got = Vec::new();
        loop {
            match q.claim(worker, CALM).expect("watchdog must not fire") {
                Claim::Task { work, stolen, .. } => got.push((work, stolen)),
                Claim::Done => return got,
            }
        }
    }

    #[test]
    fn own_deque_pops_lifo() {
        let q: StealQueues<u32> = StealQueues::new(2, true);
        // round-robin: 0,2,4 → worker 0; 1,3 → worker 1
        for i in 0..5 {
            q.push(i);
        }
        q.close();
        let got = drain_claims(&q, 0);
        let own: Vec<u32> = got.iter().filter(|(_, s)| !s).map(|&(w, _)| w).collect();
        assert_eq!(own, vec![4, 2, 0], "own deque is LIFO");
    }

    #[test]
    fn steals_come_fifo_from_victims() {
        let q: StealQueues<u32> = StealQueues::new(2, true);
        for i in 0..6 {
            q.push(i); // worker 0 gets 0,2,4; worker 1 gets 1,3,5
        }
        q.close();
        // worker 1 drains everything: its own LIFO first, then steals
        // worker 0's deque front-first
        let got = drain_claims(&q, 1);
        assert_eq!(
            got,
            vec![
                (5, false),
                (3, false),
                (1, false),
                (0, true),
                (2, true),
                (4, true)
            ]
        );
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn no_steal_mode_leaves_other_deques_alone() {
        let q: StealQueues<u32> = StealQueues::new(2, false);
        for i in 0..4 {
            q.push(i);
        }
        q.close();
        assert_eq!(drain_claims(&q, 1), vec![(3, false), (1, false)]);
        assert_eq!(q.queued(), 2, "worker 0's work is untouched");
    }

    #[test]
    fn blocked_claim_wakes_on_push_and_close() {
        let q: StealQueues<u32> = StealQueues::new(1, true);
        std::thread::scope(|s| {
            let h = s.spawn(|| drain_claims(&q, 0));
            // give the claimer a moment to block, then feed + close
            std::thread::sleep(std::time::Duration::from_millis(10));
            q.push(7);
            std::thread::sleep(std::time::Duration::from_millis(10));
            q.close();
            assert_eq!(h.join().unwrap(), vec![(7, false)]);
        });
    }

    #[test]
    fn immediate_claims_report_zero_wait() {
        let q: StealQueues<u32> = StealQueues::new(1, true);
        q.push(1);
        match q.claim(0, CALM).unwrap() {
            Claim::Task { waited, .. } => {
                assert_eq!(waited, Duration::ZERO, "fast path never blocks")
            }
            Claim::Done => panic!("work was queued"),
        }
    }

    #[test]
    fn completion_buffer_delivers_results_then_failure() {
        let c: CompletionBuffer<u32> = CompletionBuffer::new();
        let mut out = Vec::new();
        assert!(c.drain_into(&mut out).is_none());
        c.push(1);
        c.push(2);
        assert!(c.wait_drain_into(&mut out, CALM).unwrap().is_none());
        assert_eq!(out, vec![1, 2]);
        c.fail(anyhow::anyhow!("boom"));
        c.fail(anyhow::anyhow!("second, ignored"));
        assert!(c.failed());
        let err = c.drain_into(&mut out).expect("failure surfaces");
        assert_eq!(err.to_string(), "boom");
        assert!(c.drain_into(&mut out).is_none(), "failure is taken once");
    }

    #[test]
    fn starved_claim_fails_with_a_named_watchdog_error() {
        // open queues, no work, nothing beating the pulse: the claim
        // must fail after the deadline instead of hanging forever
        let q: StealQueues<u32> = StealQueues::new(1, true);
        let err = match q.claim(0, Duration::from_millis(30)) {
            Err(e) => e,
            Ok(_) => panic!("there is no work to claim"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("watchdog"), "{msg}");
        assert!(msg.contains("worker 0"), "{msg}");
    }

    #[test]
    fn pool_progress_defers_the_claim_watchdog() {
        // a sibling beating the shared pulse (as completions do) keeps
        // resetting the claim deadline: the starved worker outlasts
        // several deadline windows and still gets the late task
        let q: StealQueues<u32> = StealQueues::new(1, true);
        let pulse = q.pulse();
        std::thread::scope(|s| {
            let h = s.spawn(|| q.claim(0, Duration::from_millis(60)));
            for _ in 0..10 {
                std::thread::sleep(Duration::from_millis(20));
                pulse.beat();
            }
            q.push(7);
            match h.join().unwrap().expect("progress defers the watchdog") {
                Claim::Task {
                    work,
                    stolen,
                    waited,
                } => {
                    assert_eq!((work, stolen), (7, false));
                    assert!(waited > Duration::ZERO, "the claim blocked, so it waited");
                }
                Claim::Done => panic!("queues were never closed"),
            }
        });
    }

    #[test]
    fn retirement_handoff_reaches_a_live_sibling_then_refuses() {
        let q: StealQueues<u32> = StealQueues::new(2, true);
        q.close();
        // worker 1 retires while worker 0 is still in its claim loop:
        // the hand-off lands even though the queues are already closed
        assert!(q.push_for_retirement(9));
        assert_eq!(drain_claims(&q, 0), vec![(9, false)]);
        // worker 0 has now observed Done: nobody is left to claim
        assert!(!q.push_for_retirement(8), "no survivor remains");
    }

    #[test]
    fn retirement_handoff_refuses_without_stealing() {
        // in no-steal mode a sibling can never reach the retired
        // worker's deque, so the hand-off must refuse
        let q: StealQueues<u32> = StealQueues::new(2, false);
        assert!(!q.push_for_retirement(9));
    }

    #[test]
    fn completion_wait_times_out_with_a_named_watchdog_error() {
        let c: CompletionBuffer<u32> = CompletionBuffer::new();
        let mut out = Vec::new();
        let err = c
            .wait_drain_into(&mut out, Duration::from_millis(30))
            .expect_err("no completion will ever arrive");
        assert!(format!("{err:#}").contains("watchdog"), "{err:#}");
        assert!(out.is_empty());
    }
}
