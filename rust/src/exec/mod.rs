//! L3.5 — the sharded multi-worker pipeline executor.
//!
//! The paper extracts data parallelism *within* one SIMD pipeline; its
//! regions, however, are mutually independent, which makes the whole
//! stream shardable across **replicated pipelines** — the worker-
//! replication model of timely dataflow, applied to the coordinator. This
//! module scales any single-threaded coordinator pipeline across OS
//! threads without touching the coordinator itself: the `Rc`-based
//! scheduler, channels and nodes stay exactly as they are *inside* each
//! worker; parallelism lives one layer above.
//!
//! ## The region-boundary sharding invariant
//!
//! A shard boundary may only fall **between** regions, never inside one: a
//! [`Blob`](crate::coordinator::enumerate::Blob) (or any
//! [`Composite`](crate::coordinator::enumerate::Composite)) is enumerated
//! by exactly one worker, start to finish. (One sanctioned exception
//! exists: when [`ExecConfig::max_region_items`] is set and the factory
//! proves its region state is an associative accumulator, the [`split`]
//! layer cuts an oversized region into parts *before* planning — each
//! part then **is** a region to everything below, and the invariant
//! holds unchanged over parts.) Combined with two properties of
//! the coordinator this makes sharded execution *deterministic and
//! bit-identical* to the single-threaded run for region-local pipelines:
//!
//! 1. enumerated ensembles never mix two parents' elements (precise
//!    region signals cap every ensemble at the boundary), so a region's
//!    kernel invocations — and their floating-point grouping — depend only
//!    on that region's own elements;
//! 2. per-region state is reset at `RegionBegin` (the aggregator clones
//!    its init), so no state flows across a shard boundary.
//!
//! Pipelines whose ensembles deliberately mix regions (the dense *tagged*
//! baseline, which exists precisely to pack lanes across boundaries) lose
//! the bit-identity guarantee: sharding changes how lanes group into
//! ensembles (float rounding), and the generic merge concatenates
//! per-shard outputs — an app whose single run emits *globally* sorted or
//! coalesced results must fold the concatenation itself, as
//! `SumApp::run_sharded_with` does for its tagged mode.
//!
//! ## Pieces
//!
//! * [`plan`] — [`ShardPlan`]: contiguous, boundary-respecting partition
//!   of a **materialized** region stream with greedy item-count
//!   balancing, under a configurable [`ShardPolicy`] (shards per worker,
//!   max-shard cap, minimum shard weight).
//! * [`ingest`] — [`IngestPlanner`]: the streaming twin of the plan —
//!   converts regions arriving from a
//!   [`RegionSource`](crate::workload::source::RegionSource) into shards
//!   on the fly, against a bounded in-flight budget ([`IngestPolicy`])
//!   with backpressure and container recycling.
//! * [`factory`] — [`PipelineFactory`]/[`ShardWorker`]: how an app
//!   instantiates one **persistent** pipeline per worker thread — built
//!   once in `make_worker`, reset (not rebuilt) between shards, with
//!   [`ShardWorker::pipelines_built`] proving builds scale with workers
//!   and never shards (plus [`KernelSpawn`], which builds per-thread
//!   kernel sets — PJRT client handles are thread-confined, so each
//!   worker owns its engine).
//! * [`fault`] — [`FaultPolicy`]/[`FaultPlan`]: per-shard fault
//!   containment. The shard is the legal recovery unit (all cross-item
//!   state is region-scoped and regions never span shards), so a failed
//!   shard can be retried on a rebuilt pipeline (bit-identical, by the
//!   reuse ≡ fresh proof) or quarantined without touching its
//!   neighbours; a seeded injection harness ([`FaultyFactory`]) makes
//!   every recovery path deterministically testable.
//! * [`steal`] — [`StealQueues`]: per-worker shard deques with
//!   LIFO-local / FIFO-steal claiming ([`ClaimMode`] selects stealing,
//!   no-steal, or the legacy atomic cursor for benchmarking); every
//!   blocking wait carries a watchdog deadline tied to a pool-wide
//!   [`Pulse`] heartbeat, so stalls fail by name instead of hanging.
//! * [`pool`] — [`WorkerPool`]: `std::thread::scope`-based pool; one
//!   scheduler per worker, shards claimed from the deques. In streaming
//!   mode the calling thread drives ingest while workers execute.
//! * [`split`] — [`SubShard`]/[`SplitSource`]: intra-region sub-shard
//!   parallelism for associative aggregations. Regions heavier than
//!   [`ExecConfig::max_region_items`] are cut into parts that run as
//!   first-class regions (so stealing, retry and tracing compose
//!   unchanged), and a fixed-shape left-linear fold in part order
//!   ([`merge::RegionFolder`]) recombines partials **bit-identically**
//!   to the unsplit run. Factories opt in via
//!   [`Splittability`]; order-dependent stages refuse by name.
//! * [`merge`] — [`ExecReport`]: deterministic reassembly of per-shard
//!   outputs in original stream order plus a global
//!   [`PipelineMetrics`](crate::coordinator::metrics::PipelineMetrics)
//!   fold with a per-worker breakdown. [`StreamMerger`] releases results
//!   in stream order as shards complete, not after a global join.
//! * [`runner`] — [`ExecConfig`]/[`ShardedRunner`]: the front door
//!   (`run` for materialized streams, `run_stream`/`run_stream_with`
//!   for incremental sources, `run_stream_into` to land outputs in a
//!   [`ResultSink`](crate::io::ResultSink) — pair with the out-of-core
//!   readers in [`crate::io`] for the end-to-end constant-memory path).
//!   With [`ExecConfig::metrics`] the pool is metered through
//!   [`crate::metrics`] — per-worker latency histograms and flow
//!   counters, exact-folded into a
//!   [`MetricsReport`](crate::metrics::MetricsReport) on the report, and
//!   [`ExecConfig::progress`] adds a streaming progress heartbeat —
//!   without perturbing scheduling (metered runs stay bit-identical).
//!
//! ## Quick start
//!
//! ```no_run
//! use regatta::prelude::*;
//! use regatta::workload::regions::{gen_blobs, GenBlobSource, RegionSpec};
//!
//! let blobs = gen_blobs(1 << 20, RegionSpec::Fixed { size: 96 }, 1);
//! let factory = SumFactory::new(SumConfig::default(), KernelSpawn::Native);
//! let report = ShardedRunner::new(ExecConfig::new(8))
//!     .run(&factory, &blobs)
//!     .unwrap();
//! println!("{} sums from {} shards\n{}", report.outputs.len(),
//!          report.shards, report.worker_table());
//!
//! // The same computation as a stream: regions are generated lazily and
//! // at most 1024 are in flight at once, whatever the stream length.
//! let source = GenBlobSource::new(1 << 20, RegionSpec::Fixed { size: 96 }, 1);
//! let streamed = ShardedRunner::new(ExecConfig::new(8).streaming(1024))
//!     .run_stream(&factory, source)
//!     .unwrap();
//! assert_eq!(streamed.outputs.len(), report.outputs.len());
//! ```
//!
//! With `workers = 1` the runner degenerates to a single shard executed
//! inline — identical outputs and metrics counters to calling the app's
//! `run` directly (the `exec_equivalence` suite pins this down for
//! workers 1–8; `ingest_stream` does the same for the streaming path).

pub mod factory;
pub mod fault;
pub mod ingest;
pub mod merge;
pub mod plan;
pub mod pool;
pub mod runner;
pub mod split;
pub mod steal;

pub use factory::{
    KernelSpawn, PipelineFactory, ShardOutput, ShardWorker, Splittability, WorkerKernels,
};
pub use fault::{
    FaultKind, FaultPlan, FaultPolicy, FaultRecord, FaultShot, FaultyFactory, FaultySink,
    FaultySource, IoShot, RebuildShot,
};
pub use ingest::{ContainerPool, IngestPlanner, IngestPolicy, ShardTask};
pub use merge::{ExecReport, PartialRegion, RegionFolder, ReportBuilder, StreamMerger, WorkerStats};
pub use plan::{ShardPlan, ShardPolicy};
pub use pool::{PoolRun, ShardResult, StreamRun, WorkerPool, DEFAULT_WATCHDOG};
pub use runner::{ExecConfig, ShardedRunner, MAX_INGEST_BUFFER};
pub use split::{SplitQueue, SplitSource, SubShard};
pub use steal::{Claim, ClaimMode, CompletionBuffer, Pulse, StealQueues};
