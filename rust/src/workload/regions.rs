//! Region-structured integer streams (the §5 sum benchmarks).
//!
//! The paper streams 512 M integers divided into regions of (a) uniform
//! size and (b) size uniform in `[0, max]`. The generator reproduces both,
//! returning the stream as [`Blob`] composites (one per region) or as a
//! flat tagged stream for the in-band baseline.

use crate::coordinator::enumerate::Blob;
use crate::coordinator::tagging::Tagged;
use crate::util::prng::Prng;
use crate::workload::source::RegionSource;

/// How region sizes are drawn.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RegionSpec {
    /// Every region has exactly `size` elements (Fig. 6).
    Fixed { size: usize },
    /// Region sizes uniform in `[0, max]` (Fig. 7).
    Uniform { max: usize },
    /// Heavy-tailed bimodal mix: most regions are small (uniform in
    /// `[0, max/8]`), but one in sixteen is large (uniform in
    /// `[max/2, max]`). The skew stresses dynamic load balancing — a few
    /// shards carry most of the weight, which is exactly where
    /// work-stealing should beat static assignment (`bench ingest`).
    Skewed { max: usize },
}

impl RegionSpec {
    fn next_size(&self, rng: &mut Prng) -> usize {
        match *self {
            RegionSpec::Fixed { size } => size,
            RegionSpec::Uniform { max } => rng.below(max + 1),
            RegionSpec::Skewed { max } => {
                if rng.below(16) == 0 {
                    max / 2 + rng.below(max - max / 2 + 1)
                } else {
                    rng.below(max / 8 + 1)
                }
            }
        }
    }

    /// Expected region size (for workload sizing).
    pub fn mean(&self) -> f64 {
        match *self {
            RegionSpec::Fixed { size } => size as f64,
            RegionSpec::Uniform { max } => max as f64 / 2.0,
            // 15/16 small regions averaging max/16, 1/16 large averaging
            // 3*max/4
            RegionSpec::Skewed { max } => {
                (15.0 * (max as f64 / 16.0) + 0.75 * max as f64) / 16.0
            }
        }
    }
}

/// Lazy twin of [`gen_blobs`]: a [`RegionSource`] producing the identical
/// blob sequence (same spec, same seed ⇒ bit-identical regions in the
/// same order) one region at a time, so the streaming executor can run
/// arbitrarily long synthetic streams without materializing them —
/// memory is set by the executor's ingest budget, not by `total_items`.
///
/// With [`GenBlobSource::with_pool`] the generator draws its element
/// containers from a shared
/// [`ContainerPool`](crate::exec::ingest::ContainerPool) that streaming
/// workers refill after each shard (`SumFactory::with_elem_pool`), giving the
/// synthetic source the same zero-steady-state-allocation contract as
/// the file-backed [`BlobFileSource`](crate::io::BlobFileSource): the
/// generated *values* are bit-identical with or without a pool.
pub struct GenBlobSource {
    rng: Prng,
    spec: RegionSpec,
    total_items: usize,
    produced: usize,
    next_id: u64,
    done: bool,
    pool: Option<std::sync::Arc<crate::exec::ingest::ContainerPool<f32>>>,
}

impl GenBlobSource {
    /// Create a generator source producing `total_items` items under `spec`.
    pub fn new(total_items: usize, spec: RegionSpec, seed: u64) -> GenBlobSource {
        GenBlobSource {
            rng: Prng::new(seed),
            spec,
            total_items,
            produced: 0,
            next_id: 0,
            done: false,
            pool: None,
        }
    }

    /// Draw element containers from `pool` instead of allocating
    /// (recycled back by a pool-aware factory on the worker side).
    pub fn with_pool(
        mut self,
        pool: std::sync::Arc<crate::exec::ingest::ContainerPool<f32>>,
    ) -> GenBlobSource {
        self.pool = Some(pool);
        self
    }

    /// Regions generated so far.
    pub fn regions_produced(&self) -> u64 {
        self.next_id
    }
}

impl RegionSource for GenBlobSource {
    type Region = Blob;

    fn next_region(&mut self) -> Option<Blob> {
        if self.done || self.produced >= self.total_items {
            return None;
        }
        let size = self
            .spec
            .next_size(&mut self.rng)
            .min(self.total_items - self.produced);
        // Uniform/Skewed specs may draw 0: an empty region, which is
        // legal and exercises the empty-parent path — keep it.
        let mut elems = self
            .pool
            .as_ref()
            .and_then(|p| p.take())
            .unwrap_or_default();
        elems.extend((0..size).map(|_| self.rng.range_f32(-1.0, 1.0)));
        let blob = Blob::from_vec(self.next_id, elems);
        self.next_id += 1;
        self.produced += size;
        if size == 0 && matches!(self.spec, RegionSpec::Fixed { size: 0 }) {
            self.done = true; // degenerate fixed-zero spec cannot make progress
        }
        Some(blob)
    }
}

/// Generate regions until ~`total_items` elements have been produced
/// (the final region is truncated to land exactly on the total).
///
/// Values are uniform in `[-1, 1)`: with the sum app's threshold at 0,
/// about half the elements survive the filter — the irregular-dataflow
/// regime the framework exists for. This is the materialized drain of
/// [`GenBlobSource`], so streaming and materialized runs see the exact
/// same stream.
pub fn gen_blobs(total_items: usize, spec: RegionSpec, seed: u64) -> Vec<Blob> {
    let mut src = GenBlobSource::new(total_items, spec, seed);
    let mut blobs = Vec::new();
    while let Some(b) = src.next_region() {
        blobs.push(b);
    }
    blobs
}

/// Flatten blobs into the dense in-band representation: one tagged item
/// per element (the §5 comparison baseline).
pub fn flatten_tagged(blobs: &[Blob]) -> Vec<Tagged<f32>> {
    let mut out = Vec::with_capacity(blobs.iter().map(|b| b.elems.len()).sum());
    for b in blobs {
        for &v in &b.elems {
            out.push(Tagged::new(b.id, v));
        }
    }
    out
}

/// Split blobs into per-worker chunks of roughly `chunk_items` elements,
/// respecting region boundaries (a region is never split across chunks —
/// matching the paper, where a parent object is enumerated by a single
/// processor).
pub fn chunk_blobs(blobs: Vec<Blob>, chunk_items: usize) -> Vec<Vec<Blob>> {
    let mut chunks = Vec::new();
    let mut cur = Vec::new();
    let mut cur_items = 0usize;
    for b in blobs {
        cur_items += b.elems.len();
        cur.push(b);
        if cur_items >= chunk_items {
            chunks.push(std::mem::take(&mut cur));
            cur_items = 0;
        }
    }
    if !cur.is_empty() {
        chunks.push(cur);
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_regions_cover_total_exactly() {
        let blobs = gen_blobs(1000, RegionSpec::Fixed { size: 96 }, 1);
        let total: usize = blobs.iter().map(|b| b.elems.len()).sum();
        assert_eq!(total, 1000);
        // all but the last are exactly 96
        for b in &blobs[..blobs.len() - 1] {
            assert_eq!(b.elems.len(), 96);
        }
        assert!(blobs.last().unwrap().elems.len() <= 96);
    }

    #[test]
    fn uniform_regions_cover_total_and_vary() {
        let blobs = gen_blobs(10_000, RegionSpec::Uniform { max: 100 }, 2);
        let total: usize = blobs.iter().map(|b| b.elems.len()).sum();
        assert_eq!(total, 10_000);
        let sizes: Vec<usize> = blobs.iter().map(|b| b.elems.len()).collect();
        assert!(sizes.iter().any(|&s| s < 30));
        assert!(sizes.iter().any(|&s| s > 70));
        // mean should be near max/2
        let mean = total as f64 / sizes.len() as f64;
        assert!((mean - 50.0).abs() < 10.0, "mean={mean}");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = gen_blobs(500, RegionSpec::Uniform { max: 64 }, 7);
        let b = gen_blobs(500, RegionSpec::Uniform { max: 64 }, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn values_in_range() {
        let blobs = gen_blobs(200, RegionSpec::Fixed { size: 50 }, 3);
        for b in &blobs {
            for &v in &b.elems {
                assert!((-1.0..1.0).contains(&v));
            }
        }
    }

    #[test]
    fn flatten_preserves_order_and_tags() {
        let blobs = vec![
            Blob::from_vec(0, vec![1.0, 2.0]),
            Blob::from_vec(1, vec![3.0]),
        ];
        let flat = flatten_tagged(&blobs);
        assert_eq!(flat.len(), 3);
        assert_eq!(flat[0], Tagged::new(0, 1.0));
        assert_eq!(flat[2], Tagged::new(1, 3.0));
    }

    #[test]
    fn gen_blob_source_matches_gen_blobs_exactly() {
        for spec in [
            RegionSpec::Fixed { size: 96 },
            RegionSpec::Uniform { max: 64 },
            RegionSpec::Skewed { max: 256 },
        ] {
            let want = gen_blobs(5000, spec, 9);
            let mut src = GenBlobSource::new(5000, spec, 9);
            let mut got = Vec::new();
            while let Some(b) = src.next_region() {
                got.push(b);
            }
            assert_eq!(got, want, "{spec:?}");
            assert_eq!(src.regions_produced() as usize, want.len());
        }
    }

    #[test]
    fn pooled_gen_blob_source_is_bit_identical_and_reuses_containers() {
        use crate::exec::ingest::ContainerPool;
        use std::sync::Arc;
        let spec = RegionSpec::Fixed { size: 32 };
        let want = gen_blobs(200, spec, 13);
        let pool = Arc::new(ContainerPool::new());
        let seeded: Vec<f32> = Vec::with_capacity(64);
        let seeded_ptr = seeded.as_ptr();
        pool.put(seeded);
        let mut src = GenBlobSource::new(200, spec, 13).with_pool(pool.clone());
        let first = src.next_region().unwrap();
        assert_eq!(first.elems.as_ptr(), seeded_ptr, "container came from the pool");
        let mut got = vec![first];
        while let Some(b) = src.next_region() {
            // recycle as a worker would: values must not depend on it
            if let Some(prev) = got.last() {
                assert_eq!(prev.id + 1, b.id);
            }
            got.push(b);
        }
        assert_eq!(got, want, "pooled containers change nothing about the values");
    }

    #[test]
    fn skewed_spec_is_heavy_tailed() {
        let blobs = gen_blobs(100_000, RegionSpec::Skewed { max: 1024 }, 4);
        let total: usize = blobs.iter().map(|b| b.elems.len()).sum();
        assert_eq!(total, 100_000);
        let sizes: Vec<usize> = blobs.iter().map(|b| b.elems.len()).collect();
        let small = sizes.iter().filter(|&&s| s <= 1024 / 8).count();
        let large = sizes.iter().filter(|&&s| s >= 1024 / 2).count();
        assert!(large > 0, "tail regions must appear");
        assert!(
            small as f64 / sizes.len() as f64 > 0.8,
            "most regions are small ({small}/{})",
            sizes.len()
        );
        // the rare large regions carry a disproportionate weight share
        let large_weight: usize = sizes.iter().filter(|&&s| s >= 1024 / 2).sum();
        assert!(
            large_weight as f64 / total as f64 > 0.3,
            "tail weight share {large_weight}/{total}"
        );
        // mean() predicts the empirical mean (workload sizing contract)
        let empirical = total as f64 / sizes.len() as f64;
        let predicted = RegionSpec::Skewed { max: 1024 }.mean();
        assert!(
            (empirical - predicted).abs() / predicted < 0.25,
            "mean(): predicted {predicted}, empirical {empirical}"
        );
    }

    #[test]
    fn chunking_respects_regions() {
        let blobs = gen_blobs(1000, RegionSpec::Fixed { size: 96 }, 4);
        let n_regions = blobs.len();
        let chunks = chunk_blobs(blobs, 300);
        assert!(chunks.len() > 1);
        let total_regions: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(total_regions, n_regions);
    }
}
