//! Region-structured integer streams (the §5 sum benchmarks).
//!
//! The paper streams 512 M integers divided into regions of (a) uniform
//! size and (b) size uniform in `[0, max]`. The generator reproduces both,
//! returning the stream as [`Blob`] composites (one per region) or as a
//! flat tagged stream for the in-band baseline.

use crate::coordinator::enumerate::Blob;
use crate::coordinator::tagging::Tagged;
use crate::util::prng::Prng;

/// How region sizes are drawn.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RegionSpec {
    /// Every region has exactly `size` elements (Fig. 6).
    Fixed { size: usize },
    /// Region sizes uniform in `[0, max]` (Fig. 7).
    Uniform { max: usize },
}

impl RegionSpec {
    fn next_size(&self, rng: &mut Prng) -> usize {
        match *self {
            RegionSpec::Fixed { size } => size,
            RegionSpec::Uniform { max } => rng.below(max + 1),
        }
    }

    /// Expected region size (for workload sizing).
    pub fn mean(&self) -> f64 {
        match *self {
            RegionSpec::Fixed { size } => size as f64,
            RegionSpec::Uniform { max } => max as f64 / 2.0,
        }
    }
}

/// Generate regions until ~`total_items` elements have been produced
/// (the final region is truncated to land exactly on the total).
///
/// Values are uniform in `[-1, 1)`: with the sum app's threshold at 0,
/// about half the elements survive the filter — the irregular-dataflow
/// regime the framework exists for.
pub fn gen_blobs(total_items: usize, spec: RegionSpec, seed: u64) -> Vec<Blob> {
    let mut rng = Prng::new(seed);
    let mut blobs = Vec::new();
    let mut produced = 0usize;
    let mut id = 0u64;
    while produced < total_items {
        let size = spec.next_size(&mut rng).min(total_items - produced);
        // Uniform spec may draw 0: an empty region, which is legal and
        // exercises the empty-parent path — keep it.
        let elems: Vec<f32> = (0..size).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        blobs.push(Blob::from_vec(id, elems));
        id += 1;
        produced += size;
        if size == 0 && matches!(spec, RegionSpec::Fixed { size: 0 }) {
            break; // degenerate fixed-zero spec cannot make progress
        }
    }
    blobs
}

/// Flatten blobs into the dense in-band representation: one tagged item
/// per element (the §5 comparison baseline).
pub fn flatten_tagged(blobs: &[Blob]) -> Vec<Tagged<f32>> {
    let mut out = Vec::with_capacity(blobs.iter().map(|b| b.elems.len()).sum());
    for b in blobs {
        for &v in &b.elems {
            out.push(Tagged::new(b.id, v));
        }
    }
    out
}

/// Split blobs into per-worker chunks of roughly `chunk_items` elements,
/// respecting region boundaries (a region is never split across chunks —
/// matching the paper, where a parent object is enumerated by a single
/// processor).
pub fn chunk_blobs(blobs: Vec<Blob>, chunk_items: usize) -> Vec<Vec<Blob>> {
    let mut chunks = Vec::new();
    let mut cur = Vec::new();
    let mut cur_items = 0usize;
    for b in blobs {
        cur_items += b.elems.len();
        cur.push(b);
        if cur_items >= chunk_items {
            chunks.push(std::mem::take(&mut cur));
            cur_items = 0;
        }
    }
    if !cur.is_empty() {
        chunks.push(cur);
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_regions_cover_total_exactly() {
        let blobs = gen_blobs(1000, RegionSpec::Fixed { size: 96 }, 1);
        let total: usize = blobs.iter().map(|b| b.elems.len()).sum();
        assert_eq!(total, 1000);
        // all but the last are exactly 96
        for b in &blobs[..blobs.len() - 1] {
            assert_eq!(b.elems.len(), 96);
        }
        assert!(blobs.last().unwrap().elems.len() <= 96);
    }

    #[test]
    fn uniform_regions_cover_total_and_vary() {
        let blobs = gen_blobs(10_000, RegionSpec::Uniform { max: 100 }, 2);
        let total: usize = blobs.iter().map(|b| b.elems.len()).sum();
        assert_eq!(total, 10_000);
        let sizes: Vec<usize> = blobs.iter().map(|b| b.elems.len()).collect();
        assert!(sizes.iter().any(|&s| s < 30));
        assert!(sizes.iter().any(|&s| s > 70));
        // mean should be near max/2
        let mean = total as f64 / sizes.len() as f64;
        assert!((mean - 50.0).abs() < 10.0, "mean={mean}");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = gen_blobs(500, RegionSpec::Uniform { max: 64 }, 7);
        let b = gen_blobs(500, RegionSpec::Uniform { max: 64 }, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn values_in_range() {
        let blobs = gen_blobs(200, RegionSpec::Fixed { size: 50 }, 3);
        for b in &blobs {
            for &v in &b.elems {
                assert!((-1.0..1.0).contains(&v));
            }
        }
    }

    #[test]
    fn flatten_preserves_order_and_tags() {
        let blobs = vec![
            Blob::from_vec(0, vec![1.0, 2.0]),
            Blob::from_vec(1, vec![3.0]),
        ];
        let flat = flatten_tagged(&blobs);
        assert_eq!(flat.len(), 3);
        assert_eq!(flat[0], Tagged::new(0, 1.0));
        assert_eq!(flat[2], Tagged::new(1, 3.0));
    }

    #[test]
    fn chunking_respects_regions() {
        let blobs = gen_blobs(1000, RegionSpec::Fixed { size: 96 }, 4);
        let n_regions = blobs.len();
        let chunks = chunk_blobs(blobs, 300);
        assert!(chunks.len() > 1);
        let total_regions: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(total_regions, n_regions);
    }
}
