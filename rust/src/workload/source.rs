//! Incremental region sources: the input side of streaming execution.
//!
//! The materialized executor path consumes a complete `&[T]` region
//! stream; out-of-core inputs can't afford that. A [`RegionSource`] yields
//! region-delimited chunks one at a time, so the streaming executor
//! (`regatta::exec`) can convert regions into shards on the fly against a
//! bounded in-flight budget — memory is governed by the budget, never by
//! stream length.
//!
//! A source is pulled from exactly one thread (the ingest driver), so it
//! needs no synchronization and may own mutable generator state (a PRNG,
//! a file reader, a decoder). Region *boundaries* are the source's
//! responsibility: one yielded item is one region, and the executor never
//! splits it (see the region-boundary invariant in `regatta::exec`).
//!
//! Implementations here:
//!
//! * [`SliceSource`] — adapts a materialized `&[T]` (clones per region),
//!   so every materialized workload can also be replayed as a stream.
//! * [`IterSource`] — adapts any iterator of owned regions.
//! * [`GenBlobSource`](crate::workload::regions::GenBlobSource) — the
//!   lazy twin of [`gen_blobs`](crate::workload::regions::gen_blobs),
//!   producing the identical blob sequence without materializing it.
//! * [`BlobFileSource`](crate::io::BlobFileSource) /
//!   [`TextSource`](crate::io::TextSource) — out-of-core readers over
//!   `.rgn` containers and line-delimited taxi text (`regatta::io`).

use anyhow::Result;

/// A stream of regions, pulled one region at a time.
pub trait RegionSource {
    /// The region/composite type (one item = one whole region).
    type Region;

    /// Pull the next region, or `None` at end of stream.
    fn next_region(&mut self) -> Option<Self::Region>;

    /// Fallible pull: like [`RegionSource::next_region`], but a source
    /// that can fail *transiently* (network hiccup, injected fault) may
    /// return `Err` without ending the stream — the ingest driver
    /// retries the same pull under its bounded retry-with-backoff
    /// budget (`Ok(None)` still means a clean end of stream). The
    /// default forwards to `next_region`, so infallible sources never
    /// see retries.
    fn try_next_region(&mut self) -> Result<Option<Self::Region>> {
        Ok(self.next_region())
    }

    /// `(lower, upper)` bound on the number of regions still to come —
    /// advisory only (sizing hints for planners), like
    /// [`Iterator::size_hint`].
    fn size_hint(&self) -> (usize, Option<usize>) {
        (0, None)
    }

    /// Surface any deferred failure once the stream has ended.
    ///
    /// [`RegionSource::next_region`] returns a bare `Option`, so a
    /// fallible source (file reader, decoder, network) cannot report
    /// *why* it ended: it stashes the first error, returns `None`, and
    /// the executor calls `close` after draining — turning a silently
    /// short stream into a named `run_stream*` failure. Infallible
    /// sources keep the default `Ok(())`.
    fn close(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Boxed sources forward, so callers that pick a source at runtime
/// (`--input` file vs. generator) can hand the executor a
/// `Box<dyn RegionSource<Region = T>>`.
impl<S: RegionSource + ?Sized> RegionSource for Box<S> {
    type Region = S::Region;

    fn next_region(&mut self) -> Option<S::Region> {
        (**self).next_region()
    }

    fn try_next_region(&mut self) -> Result<Option<S::Region>> {
        (**self).try_next_region()
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (**self).size_hint()
    }

    fn close(&mut self) -> Result<()> {
        (**self).close()
    }
}

/// [`RegionSource`] over a materialized slice: clones each region on
/// demand. Lets every existing workload drive the streaming executor, and
/// pins down streaming-vs-materialized equivalence in tests.
pub struct SliceSource<'a, T: Clone> {
    items: &'a [T],
    next: usize,
}

impl<'a, T: Clone> SliceSource<'a, T> {
    /// Create a source over the slice.
    pub fn new(items: &'a [T]) -> SliceSource<'a, T> {
        SliceSource { items, next: 0 }
    }
}

impl<T: Clone> RegionSource for SliceSource<'_, T> {
    type Region = T;

    fn next_region(&mut self) -> Option<T> {
        let item = self.items.get(self.next)?.clone();
        self.next += 1;
        Some(item)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.items.len() - self.next;
        (left, Some(left))
    }
}

/// [`RegionSource`] over any iterator of owned regions.
pub struct IterSource<I> {
    iter: I,
}

impl<I: Iterator> IterSource<I> {
    /// Create a source over the iterator.
    pub fn new(iter: I) -> IterSource<I> {
        IterSource { iter }
    }
}

impl<I: Iterator> RegionSource for IterSource<I> {
    type Region = I::Item;

    fn next_region(&mut self) -> Option<I::Item> {
        self.iter.next()
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.iter.size_hint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_source_replays_in_order() {
        let items = vec![10u32, 20, 30];
        let mut src = SliceSource::new(&items);
        assert_eq!(src.size_hint(), (3, Some(3)));
        assert_eq!(src.next_region(), Some(10));
        assert_eq!(src.next_region(), Some(20));
        assert_eq!(src.size_hint(), (1, Some(1)));
        assert_eq!(src.next_region(), Some(30));
        assert_eq!(src.next_region(), None);
        assert_eq!(src.next_region(), None, "stays exhausted");
    }

    #[test]
    fn iter_source_adapts_iterators() {
        let mut src = IterSource::new((0..4u64).map(|i| i * i));
        let mut got = Vec::new();
        while let Some(r) = src.next_region() {
            got.push(r);
        }
        assert_eq!(got, vec![0, 1, 4, 9]);
    }
}
