//! Synthetic DIBS-like `tstcsv` workload (the paper's "taxi" app input).
//!
//! The paper replays DIBS's `tstcsv->csv` benchmark: lines of text, each
//! with a tag, a variable-length list of GPS coordinate pairs written as
//! `{lat,lon}`, and other data. DIBS's corpus is not available offline, so
//! the generator synthesizes text matching the statistics the paper
//! reports — **average line length 1397 characters and 45 coordinate
//! pairs per line** — which are exactly the quantities that determine
//! stage occupancy (91 % / 9 % full ensembles) and hence the Fig. 8
//! result shapes. See DESIGN.md §Substitutions.
//!
//! Line format:
//!
//! ```text
//! T<tag>,{-37.8136,144.9631},{...},...,<filler>\n
//! ```
//!
//! Filler is brace-free so stage 1's candidate detector stays honest.

use std::sync::Arc;

use crate::coordinator::enumerate::Composite;
use crate::util::prng::Prng;

/// Paper statistic: mean characters per line.
pub const PAPER_AVG_LINE_LEN: usize = 1397;
/// Paper statistic: mean coordinate pairs per line.
pub const PAPER_AVG_PAIRS: usize = 45;

/// One line of the input, viewing a shared text buffer
/// (the paper's "stream of line start indices and line lengths").
#[derive(Debug, Clone)]
pub struct TaxiLine {
    /// Shared raw text (the "GPU memory" buffer; `Arc`: all worker
    /// processors view the same device memory).
    pub text: Arc<Vec<u8>>,
    /// Byte offset of the line start in `text`.
    pub start: usize,
    /// Line length in bytes.
    pub len: usize,
    /// Numeric tag parsed from the line head (parsed once per line).
    pub tag: u32,
}

impl TaxiLine {
    /// The line's bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.text[self.start..self.start + self.len]
    }

    /// Absolute position of a line-relative offset.
    pub fn abs(&self, off: u32) -> usize {
        self.start + off as usize
    }
}

impl Composite for TaxiLine {
    fn count(&self) -> usize {
        self.len // enumerate the line's characters
    }
}

/// A generated workload: the raw text plus its line index.
#[derive(Debug, Clone)]
pub struct TaxiWorkload {
    /// The raw text buffer, shared by every line.
    pub text: Arc<Vec<u8>>,
    /// Line index into `text`, in stream order.
    pub lines: Vec<TaxiLine>,
    /// Ground truth: total well-formed coordinate pairs in the text.
    pub total_pairs: usize,
}

/// Tunable generator parameters (defaults = the paper's statistics).
#[derive(Debug, Clone, Copy)]
pub struct TaxiGenConfig {
    /// Mean coordinate pairs per line.
    pub avg_pairs: usize,
    /// Mean characters per line.
    pub avg_line_len: usize,
}

impl Default for TaxiGenConfig {
    fn default() -> Self {
        TaxiGenConfig {
            avg_pairs: PAPER_AVG_PAIRS,
            avg_line_len: PAPER_AVG_LINE_LEN,
        }
    }
}

const FILLER: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789 ;:";

fn push_coord(out: &mut Vec<u8>, rng: &mut Prng) {
    // GPS-ish coordinates with 1–4 fractional digits
    let lat = rng.range_f32(-90.0, 90.0);
    let lon = rng.range_f32(-180.0, 180.0);
    let dec = 1 + rng.below(4);
    out.push(b'{');
    out.extend_from_slice(format!("{lat:.dec$}").as_bytes());
    out.push(b',');
    out.extend_from_slice(format!("{lon:.dec$}").as_bytes());
    out.push(b'}');
}

/// Generate `n_lines` lines matching the configured statistics.
pub fn generate(n_lines: usize, cfg: TaxiGenConfig, seed: u64) -> TaxiWorkload {
    let mut rng = Prng::new(seed);
    let mut text = Vec::with_capacity(n_lines * (cfg.avg_line_len + 1));
    let mut spans = Vec::with_capacity(n_lines);
    let mut total_pairs = 0usize;
    for i in 0..n_lines {
        let start = text.len();
        let tag = i as u32;
        text.extend_from_slice(format!("T{tag},").as_bytes());
        // pairs per line: uniform in [1, 2*avg) → mean ≈ avg
        let pairs = 1 + rng.below((2 * cfg.avg_pairs).max(2) - 1);
        for p in 0..pairs {
            if p > 0 {
                text.push(b',');
            }
            push_coord(&mut text, &mut rng);
        }
        total_pairs += pairs;
        // brace-free filler up to the target length (uniform around avg)
        let target = {
            let lo = cfg.avg_line_len / 2;
            let hi = cfg.avg_line_len * 3 / 2;
            lo + rng.below(hi - lo + 1)
        };
        text.push(b',');
        while text.len() - start < target {
            text.push(FILLER[rng.below(FILLER.len())]);
        }
        let len = text.len() - start;
        text.push(b'\n');
        spans.push((start, len, tag));
    }
    let text = Arc::new(text);
    let lines = spans
        .into_iter()
        .map(|(start, len, tag)| TaxiLine {
            text: text.clone(),
            start,
            len,
            tag,
        })
        .collect();
    TaxiWorkload {
        text,
        lines,
        total_pairs,
    }
}

/// Replicate a workload `k`× (the paper scales input size by replicating
/// the DIBS file). Tags restart per replica; text is shared.
pub fn replicate(base: &TaxiWorkload, k: usize) -> TaxiWorkload {
    let mut lines = Vec::with_capacity(base.lines.len() * k);
    for _ in 0..k {
        lines.extend(base.lines.iter().cloned());
    }
    TaxiWorkload {
        text: base.text.clone(),
        lines,
        total_pairs: base.total_pairs * k,
    }
}

/// Split a workload's lines into chunks of `lines_per_chunk` for the
/// multi-worker machine.
pub fn chunk_lines(w: &TaxiWorkload, lines_per_chunk: usize) -> Vec<Vec<TaxiLine>> {
    w.lines
        .chunks(lines_per_chunk.max(1))
        .map(|c| c.to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_pairs_naive(text: &[u8]) -> usize {
        // independent ground-truth: regex-free scan for {num,num}
        let s = String::from_utf8_lossy(text);
        let mut n = 0;
        for (i, _) in s.match_indices('{') {
            if let Some(end) = s[i..].find('}') {
                let body = &s[i + 1..i + end];
                let mut it = body.splitn(2, ',');
                let a = it.next().unwrap_or("");
                let b = it.next().unwrap_or("");
                if !a.is_empty()
                    && !b.is_empty()
                    && a.parse::<f64>().is_ok()
                    && b.parse::<f64>().is_ok()
                {
                    n += 1;
                }
            }
        }
        n
    }

    #[test]
    fn statistics_match_paper_targets() {
        let w = generate(200, TaxiGenConfig::default(), 1);
        let avg_len: f64 =
            w.lines.iter().map(|l| l.len as f64).sum::<f64>() / w.lines.len() as f64;
        assert!(
            (avg_len - PAPER_AVG_LINE_LEN as f64).abs() < 150.0,
            "avg_len={avg_len}"
        );
        let avg_pairs = w.total_pairs as f64 / w.lines.len() as f64;
        assert!(
            (avg_pairs - PAPER_AVG_PAIRS as f64).abs() < 8.0,
            "avg_pairs={avg_pairs}"
        );
    }

    #[test]
    fn ground_truth_matches_scan() {
        let w = generate(20, TaxiGenConfig::default(), 2);
        assert_eq!(w.total_pairs, count_pairs_naive(&w.text));
    }

    #[test]
    fn lines_index_text_correctly() {
        let w = generate(10, TaxiGenConfig::default(), 3);
        for l in &w.lines {
            let bytes = l.bytes();
            assert_eq!(bytes[0], b'T');
            assert!(!bytes.contains(&b'\n'));
            let tag_text: String = bytes[1..]
                .iter()
                .take_while(|&&b| b != b',')
                .map(|&b| b as char)
                .collect();
            assert_eq!(tag_text.parse::<u32>().unwrap(), l.tag);
        }
    }

    #[test]
    fn replicate_scales_lines_and_truth() {
        let base = generate(5, TaxiGenConfig::default(), 4);
        let big = replicate(&base, 3);
        assert_eq!(big.lines.len(), 15);
        assert_eq!(big.total_pairs, base.total_pairs * 3);
        assert!(Arc::ptr_eq(&big.text, &base.text));
    }

    #[test]
    fn chunking_covers_all_lines() {
        let w = generate(13, TaxiGenConfig::default(), 5);
        let chunks = chunk_lines(&w, 4);
        assert_eq!(chunks.iter().map(Vec::len).sum::<usize>(), 13);
        assert_eq!(chunks.len(), 4);
    }

    #[test]
    fn deterministic() {
        let a = generate(5, TaxiGenConfig::default(), 9);
        let b = generate(5, TaxiGenConfig::default(), 9);
        assert_eq!(*a.text, *b.text);
    }
}
