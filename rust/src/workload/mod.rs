//! Workload generators for the paper's evaluation.
//!
//! * [`regions`] — integer streams divided into fixed-size or
//!   uniformly-random regions (the §5 "sum" benchmarks, Figs 6/7).
//! * [`taxi`] — synthetic DIBS-like `tstcsv` text: tagged lines of GPS
//!   coordinate pairs matching the paper's corpus statistics (no DIBS
//!   data ships with this repo; see DESIGN.md substitution notes).
//! * [`source`] — the [`RegionSource`](source::RegionSource) trait:
//!   incremental, region-delimited input for the streaming executor,
//!   plus slice/iterator adapters (the lazy blob generator lives in
//!   [`regions::GenBlobSource`]).

pub mod regions;
pub mod source;
pub mod taxi;
