//! Regenerates **Figure 7**: execution time vs *maximum* region size with
//! sizes uniform in [0, max]. Run: `cargo bench --bench fig7_variable_regions`
//!
//! Expected shape (paper): the sharp alignment peaks of Fig. 6 smooth
//! out; larger regions still cost less abstraction overhead.

use regatta::bench::figures::{fig7, SweepConfig};

fn main() {
    let mut cfg = SweepConfig::default();
    if let Ok(n) = std::env::var("REGATTA_BENCH_ITEMS") {
        cfg.items = n.parse().expect("REGATTA_BENCH_ITEMS");
    }
    let rows = fig7(&cfg).expect("fig7 sweep");
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    println!(
        "\nshape check: time(max={}) = {:.4}s vs time(max={}) = {:.4}s  ({})",
        first.region,
        first.seconds,
        last.region,
        last.seconds,
        if last.seconds < first.seconds {
            "larger regions cheaper, as in paper"
        } else {
            "MISMATCH vs paper"
        }
    );
}
