//! §5 abstraction-penalty check: applications that do not use signals or
//! enumeration pay ~nothing for the machinery (paper: "verified to be
//! negligible"). Run: `cargo bench --bench abstraction_penalty`

use regatta::bench::figures::{abstraction_penalty, SweepConfig};

fn main() {
    let mut cfg = SweepConfig::default();
    cfg.items = std::env::var("REGATTA_BENCH_ITEMS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 19);
    let (raw, coord, signals) = abstraction_penalty(&cfg).expect("penalty bench");
    println!(
        "\ncoordinator overhead vs raw loop: {:+.1}% (signals unused), {:+.1}% (aligned regions)",
        100.0 * (coord / raw - 1.0),
        100.0 * (signals / raw - 1.0)
    );
}
