//! Ablation A2: the Fig-6 mechanism tracks the SIMD width — sweeping
//! w ∈ {32, 64, 128, 256} moves the occupancy minima with it.
//! Run: `cargo bench --bench ablation_width`

use regatta::bench::figures::{ablation_width, SweepConfig};

fn main() {
    let mut cfg = SweepConfig::default();
    cfg.items = std::env::var("REGATTA_BENCH_ITEMS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 19);
    let out = ablation_width(&cfg, &[32, 64, 128, 256]).expect("width ablation");
    println!("\nshape check: occupancy at region=w vs region=w+8 per width:");
    for (w, rows) in &out {
        let occ = |r: usize| {
            rows.iter()
                .find(|x| x.region == r)
                .map(|x| x.occupancy)
                .unwrap_or(0.0)
        };
        println!(
            "  w={w}: occ(w)={:.2} occ(w+8)={:.2} ({})",
            occ(*w),
            occ(*w + 8),
            if occ(*w) > occ(*w + 8) { "minimum tracks width" } else { "MISMATCH" }
        );
    }
}
