//! Regenerates **Figure 6**: execution time vs fixed region size for the
//! sum app (paper §5). Run: `cargo bench --bench fig6_fixed_regions`
//!
//! Expected shape (paper): time falls sharply as region size grows toward
//! the SIMD width, local minima at multiples of the width, sharp jumps
//! just past each multiple.

use regatta::bench::figures::{fig6, SweepConfig};

fn main() {
    let mut cfg = SweepConfig::default();
    if let Ok(n) = std::env::var("REGATTA_BENCH_ITEMS") {
        cfg.items = n.parse().expect("REGATTA_BENCH_ITEMS");
    }
    let rows = fig6(&cfg).expect("fig6 sweep");
    // shape check: width-aligned minima — time(w) < time(w+8)
    let at = |r: usize| rows.iter().find(|x| x.region == r).map(|x| x.seconds);
    if let (Some(tw), Some(twp)) = (at(cfg.width), at(cfg.width + 8)) {
        println!(
            "\nshape check: time({}) = {:.4}s {} time({}) = {:.4}s  ({})",
            cfg.width,
            tw,
            if tw < twp { "<" } else { ">=" },
            cfg.width + 8,
            twp,
            if tw < twp { "aligned minimum reproduced" } else { "MISMATCH vs paper" }
        );
    }
}
