//! Ablation A3 (paper §6 future work): per-lane context via dense tags +
//! segmented reduction (signal-free, full occupancy) vs signal-delimited
//! ensembles, across region sizes — plus the scheduling-policy ablation.
//! Run: `cargo bench --bench ablation_lanectx`
//!
//! Expected: lane-context wins for regions well below the SIMD width
//! (occupancy dominates); signals win for large regions (representation
//! overhead dominates) — the §5 tradeoff, quantified.

use regatta::bench::figures::{ablation_lanectx, ablation_policy, SweepConfig};

fn main() {
    let mut cfg = SweepConfig::default();
    cfg.items = std::env::var("REGATTA_BENCH_ITEMS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 19);
    let rows = ablation_lanectx(&cfg).expect("lanectx ablation");
    let small = rows.first().unwrap();
    let large = rows.last().unwrap();
    println!("\nshape checks:");
    println!(
        "  small regions ({}): lane-ctx {:.4}s vs signals {:.4}s ({})",
        small.0,
        small.2,
        small.1,
        if small.2 < small.1 { "lane-ctx wins, as expected" } else { "signals win" }
    );
    println!(
        "  large regions ({}): signals {:.4}s vs lane-ctx {:.4}s ({})",
        large.0,
        large.1,
        large.2,
        if large.1 < large.2 { "signals win, as expected" } else { "lane-ctx wins" }
    );

    ablation_policy(&cfg, 48).expect("policy ablation");
}
