//! Shard-scaling harness (L3.5 baseline curve): sum-app throughput vs
//! worker count × region size. Region size sets the region-boundary
//! frequency — the Fig. 6/7 axis — now crossed with a scaling dimension.
//! Run: `cargo bench --bench scaling_shards`
//!
//! Env knobs: `REGATTA_BENCH_ITEMS` (stream size), `REGATTA_BENCH_BACKEND`
//! (`native`|`xla`; default native so the harness runs without AOT
//! artifacts), `REGATTA_BENCH_WORKERS` (comma list), `REGATTA_BENCH_JSON`
//! (artifact path; default `BENCH_scaling_shards.json`), plus the usual
//! `REGATTA_BENCH_ITERS` / `REGATTA_BENCH_WARMUP`.

use regatta::bench::figures::{scaling_shards, scaling_to_json, BackendSel, SweepConfig};

fn main() {
    let mut cfg = SweepConfig {
        backend: BackendSel::Native,
        ..SweepConfig::default()
    };
    if let Ok(n) = std::env::var("REGATTA_BENCH_ITEMS") {
        cfg.items = n.parse().expect("REGATTA_BENCH_ITEMS");
    }
    if let Ok(b) = std::env::var("REGATTA_BENCH_BACKEND") {
        cfg.backend = b.parse().expect("REGATTA_BENCH_BACKEND");
    }
    let workers: Vec<usize> = match std::env::var("REGATTA_BENCH_WORKERS") {
        Ok(s) => s
            .split(',')
            .map(|p| p.trim().parse().expect("REGATTA_BENCH_WORKERS"))
            .collect(),
        Err(_) => vec![1, 2, 4, 8],
    };
    // small regions = frequent boundaries (occupancy-bound pipelines);
    // large regions = rare boundaries (coarse shards, planner stress)
    let w = cfg.width;
    let regions = [w / 8, w, 8 * w];
    let rows = scaling_shards(&cfg, &workers, &regions).expect("scaling sweep");

    // CI uploads this next to BENCH_hotpath.json / BENCH_ingest.json
    let json_path = std::env::var("REGATTA_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_scaling_shards.json".to_string());
    std::fs::write(&json_path, scaling_to_json(&rows)).expect("write scaling JSON");
    println!("wrote {json_path}");

    // shape check: at every region size, max workers should not be slower
    // than 1 worker (speedup >= 1 within noise)
    for &region in &regions {
        let series: Vec<_> = rows.iter().filter(|r| r.region == region).collect();
        if let (Some(first), Some(last)) = (series.first(), series.last()) {
            println!(
                "\nshape check: region {region}: {}w {:.4}s -> {}w {:.4}s ({:.2}x)",
                first.workers, first.seconds, last.workers, last.seconds, last.speedup
            );
        }
    }
}
