//! Coordinator micro-benchmarks: per-operation costs of the L3 hot path.
//! Run: `cargo bench --bench micro_coordinator`
//!
//! These feed the §Perf analysis in EXPERIMENTS.md: the coordinator's
//! per-ensemble overhead (queue ops + credit bookkeeping + metrics) must
//! stay well under one PJRT kernel invocation.

use std::rc::Rc;
use std::time::Instant;

use regatta::bench::{BenchConfig, Table};
use regatta::coordinator::channel::Channel;
use regatta::coordinator::signal::SignalKind;
use regatta::coordinator::tagging::densify_tags;
use regatta::runtime::kernels::KernelSet;
use regatta::runtime::{ArtifactStore, Engine};
use regatta::util::stats::fmt_duration;

fn time_per_op<F: FnMut()>(ops: u64, mut f: F) -> f64 {
    let t = Instant::now();
    f();
    t.elapsed().as_secs_f64() / ops as f64
}

fn main() {
    let _ = BenchConfig::from_env();
    let mut t = Table::new(&["operation", "per-op"]);

    // queue push+pop through a channel (the per-item L3 cost)
    const N: u64 = 1_000_000;
    let ch: Rc<Channel<u64>> = Channel::new(1 << 20, 1 << 10);
    let mut buf = Vec::with_capacity(128);
    let per = time_per_op(N, || {
        for i in 0..N {
            ch.push(i);
        }
        let mut got = 0;
        while got < N {
            got += ch.pop_data_into(128, &mut buf) as u64;
        }
    });
    t.row(&["channel push+pop (per item)".into(), fmt_duration(per)]);

    // signal emit + credit transfer + pop (per region boundary)
    const S: u64 = 200_000;
    let per = time_per_op(S, || {
        for _ in 0..S {
            ch.push(1);
            ch.emit_signal(SignalKind::Custom(1));
            ch.pop_data_into(1, &mut buf);
            ch.take_head_signal_credit();
            ch.pop_signal();
        }
    });
    t.row(&["signal emit+consume (per signal)".into(), fmt_duration(per)]);

    // tag densification at width 128 (per ensemble, tagged baseline)
    let tags: Vec<u64> = (0..128u64).map(|i| i / 45).collect();
    let (mut local, mut uniq) = (Vec::new(), Vec::new());
    const D: u64 = 100_000;
    let per = time_per_op(D, || {
        for _ in 0..D {
            densify_tags(&tags, &mut local, &mut uniq);
        }
    });
    t.row(&["densify_tags w=128 (per ensemble)".into(), fmt_duration(per)]);

    // native kernel ensemble (L3 floor without PJRT)
    let ksn = KernelSet::native(128);
    let vals = vec![0.5f32; 128];
    let mask = vec![1i32; 128];
    const K: u64 = 100_000;
    let per = time_per_op(K, || {
        for _ in 0..K {
            ksn.sum_region(&vals, &mask, 0.0).unwrap();
        }
    });
    t.row(&["native sum_region w=128".into(), fmt_duration(per)]);

    // PJRT kernel invocation (the SIMD machine's cost unit)
    if let Ok(store) = ArtifactStore::discover() {
        let eng = Engine::new(store).unwrap();
        let ks = KernelSet::xla(&eng, 128).unwrap();
        ks.sum_region(&vals, &mask, 0.0).unwrap(); // warm
        const X: u64 = 2_000;
        let per = time_per_op(X, || {
            for _ in 0..X {
                ks.sum_region(&vals, &mask, 0.0).unwrap();
            }
        });
        t.row(&["PJRT sum_region w=128 (cost unit)".into(), fmt_duration(per)]);

        let wl = ks.window_len();
        let windows = vec![0i32; 128 * wl];
        ks.coord_parse(&windows, &mask).unwrap();
        const P: u64 = 500;
        let per = time_per_op(P, || {
            for _ in 0..P {
                ks.coord_parse(&windows, &mask).unwrap();
            }
        });
        t.row(&["PJRT coord_parse w=128".into(), fmt_duration(per)]);
    } else {
        eprintln!("(artifacts missing — PJRT rows skipped; run `make artifacts`)");
    }

    println!("== Coordinator micro-benchmarks ==");
    t.print();
}
