//! Regenerates **Figure 8**: the taxi app under its three context
//! strategies (pure enumeration / hybrid / pure tagging) vs input size,
//! plus the §5 occupancy statistic (paper: stage 1 91 % full, stage 2
//! 9 % full in the pure-enumeration variant).
//!
//! Run: `cargo bench --bench fig8_taxi`
//! Expected shape: hybrid fastest; pure tagging ≈30 % slower than hybrid
//! at the largest input.

use regatta::apps::taxi::TaxiVariant;
use regatta::bench::figures::{fig8, SweepConfig};

fn main() {
    let cfg = SweepConfig::default();
    let base_lines = std::env::var("REGATTA_BENCH_LINES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let rows = fig8(&cfg, base_lines, &[1, 2, 4, 8]).expect("fig8 sweep");

    let max_scale = rows.iter().map(|r| r.scale).max().unwrap();
    let at = |v: TaxiVariant| {
        rows.iter()
            .find(|r| r.scale == max_scale && r.variant == v)
            .unwrap()
    };
    let e = at(TaxiVariant::Enumerated);
    let h = at(TaxiVariant::Hybrid);
    let t = at(TaxiVariant::Tagged);
    println!("\nshape checks at scale {max_scale}:");
    println!(
        "  hybrid {:.4}s < pure-enum {:.4}s: {}",
        h.seconds,
        e.seconds,
        h.seconds < e.seconds
    );
    println!(
        "  pure-tagging {:.4}s vs hybrid: {:.2}x (paper: ~1.3x)",
        t.seconds,
        t.seconds / h.seconds
    );
    println!(
        "  occupancy split (pure-enum): stage1 {:.0}% / stage2 {:.0}% full (paper: 91%/9%)",
        100.0 * e.stage1_full,
        100.0 * e.stage2_full
    );
}
