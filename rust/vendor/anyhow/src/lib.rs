//! Offline vendored subset of the `anyhow` API (the container image has no
//! crates.io access). Implements exactly the surface this workspace uses:
//!
//! * [`Error`] — boxed error with a context chain; `Display` prints the
//!   outermost message, `{:#}` prints the whole chain joined by `": "`,
//!   `Debug` prints the chain as a `Caused by:` list.
//! * [`Result`] — alias with `Error` as the default error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros.
//! * [`Context`] — `.context(...)` / `.with_context(...)` on `Result`
//!   (both std errors and `anyhow::Error`) and on `Option`.
//!
//! Deliberately omitted: downcasting, backtraces, `#[source]` chaining of
//! live error values (sources are flattened to strings at conversion
//! time). Swap this path dependency for the real crate when a registry is
//! available — no call site changes needed.

use std::fmt::{self, Debug, Display};

/// Boxed error with a chain of context messages (outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `std::result::Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error in an outer context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The messages of this error and its causes, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }

    /// The innermost cause's message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }

    fn from_std(e: &(dyn std::error::Error + 'static)) -> Error {
        Error {
            msg: e.to_string(),
            source: e.source().map(|s| Box::new(Error::from_std(s))),
        }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain on one line, like real anyhow.
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` intentionally does NOT implement `std::error::Error`: that is
// what keeps this blanket conversion coherent (same trick as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// Attach context to a fallible value. Implemented for `Result` over std
/// errors, `Result` over [`Error`], and `Option` (missing value becomes
/// the context message).
pub trait Context<T, E> {
    /// Wrap the error value with a fixed context message.
    fn context<C: Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value with a lazily evaluated context message.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::from(io_err()).context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing thing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(e.root_cause(), "missing thing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value for {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "no value for k");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("12"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
        let e = anyhow!("literal");
        assert_eq!(e.to_string(), "literal");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing thing");
    }
}
