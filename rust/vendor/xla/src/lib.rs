//! Offline stub of the `xla-rs` PJRT binding surface used by this
//! workspace. The container image cannot build the real `xla_extension`
//! bindings (no network, no prebuilt XLA), so this crate keeps the crate
//! graph compiling and fails **at runtime, with a clear message**, the
//! moment a PJRT client is requested. The coordinator's native kernel
//! backend (`regatta::runtime::native`) is unaffected and fully
//! functional.
//!
//! To run the measured XLA configuration, point the `xla` dependency in
//! `rust/Cargo.toml` at the real xla-rs bindings; the API here mirrors the
//! subset the workspace calls (`PjRtClient`, `PjRtLoadedExecutable`,
//! `Literal`, `HloModuleProto`, `XlaComputation`).
//!
//! [`Literal`] is implemented for real (shape/count bookkeeping only, no
//! device buffers) because literal construction is exercised by unit
//! tests without any PJRT client.

use std::fmt;

/// Stub error type; converts into `anyhow::Error` at call sites.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: built against the offline `xla` stub (no PJRT runtime); \
             use the native kernel backend, or link the real xla-rs bindings"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias (mirrors xla-rs).
pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    I32,
    I64,
    U8,
    U32,
}

mod sealed {
    pub trait Sealed {}
}

/// Rust scalar types storable in a [`Literal`].
pub trait NativeType: sealed::Sealed + Copy {
    const TY: ElementType;
}

macro_rules! native {
    ($($t:ty => $e:ident),* $(,)?) => {
        $(
            impl sealed::Sealed for $t {}
            impl NativeType for $t {
                const TY: ElementType = ElementType::$e;
            }
        )*
    };
}

native!(f32 => F32, f64 => F64, i32 => I32, i64 => I64, u8 => U8, u32 => U32);

/// Host-side literal: shape and element-type bookkeeping only (the stub
/// holds no data — nothing can execute to read it back).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(xs: &[T]) -> Literal {
        Literal {
            ty: T::TY,
            dims: vec![xs.len() as i64],
        }
    }

    /// Total number of elements.
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let new_count: i64 = dims.iter().product();
        if new_count as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            ty: self.ty,
            dims: dims.to_vec(),
        })
    }

    /// Copy out as a typed vector — unavailable in the stub (nothing can
    /// have produced device data).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    /// Decompose a tuple literal — unavailable in the stub.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module — stub never parses, so values cannot exist.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse HLO text from a file — always fails in the stub.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({path})"
        )))
    }
}

/// Computation wrapper around a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side result buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Fetch the buffer as a host literal — unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given inputs — unreachable in the stub (no client
    /// can compile an executable).
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single entry point, and
/// in the stub it fails immediately with an actionable message.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU PJRT client — always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation — unreachable in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_bookkeeping() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.element_count(), 6);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn client_fails_with_actionable_message() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("stub"), "{err}");
    }
}
