//! Apps on the real XLA backend: end-to-end through artifacts + PJRT
//! (requires `make artifacts`). These are the measured configurations of
//! the figure benches, validated for correctness at small scale.
//!
//! All `#[ignore]`d by default: they need the AOT artifacts **and** a
//! real PJRT runtime (the workspace links an offline `xla` stub — see
//! rust/vendor/xla). Run with `cargo test -- --ignored` when provisioned.

use std::rc::Rc;

use regatta::apps::sum::{reference_sums, SumApp, SumConfig, SumMode, SumShape};
use regatta::apps::taxi::{reference_pairs, sort_pairs, TaxiApp, TaxiConfig, TaxiVariant};
use regatta::runtime::kernels::KernelSet;
use regatta::runtime::{ArtifactStore, Engine};
use regatta::workload::regions::{gen_blobs, RegionSpec};
use regatta::workload::taxi::{generate, TaxiGenConfig};

fn engine() -> Engine {
    Engine::new(ArtifactStore::discover().expect("make artifacts")).expect("pjrt")
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn sum_app_xla_fused_matches_reference() {
    let eng = engine();
    let ks = Rc::new(KernelSet::xla(&eng, 32).unwrap());
    let blobs = gen_blobs(3000, RegionSpec::Fixed { size: 48 }, 21);
    let app = SumApp::new(
        SumConfig {
            width: 32,
            data_cap: 512,
            signal_cap: 128,
            ..Default::default()
        },
        ks,
    );
    let report = app.run(&blobs).unwrap();
    let want = reference_sums(&blobs, 0.0);
    assert_eq!(report.outputs.len(), want.len());
    for ((gi, gv), (wi, wv)) in report.outputs.iter().zip(&want) {
        assert_eq!(gi, wi);
        assert!((gv - wv).abs() < 1e-2 * (1.0 + wv.abs()), "{gv} vs {wv}");
    }
    assert!(report.invocations > 0);
    assert!(report.elapsed > 0.0);
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn sum_app_xla_all_modes_agree() {
    let eng = engine();
    let ks = Rc::new(KernelSet::xla(&eng, 32).unwrap());
    let blobs = gen_blobs(800, RegionSpec::Fixed { size: 17 }, 5);
    let want = reference_sums(&blobs, 0.0);
    for (mode, shape) in [
        (SumMode::Enumerated, SumShape::Fused),
        (SumMode::Enumerated, SumShape::TwoStage),
        (SumMode::Tagged, SumShape::Fused),
    ] {
        let app = SumApp::new(
            SumConfig {
                width: 32,
                mode,
                shape,
                data_cap: 256,
                signal_cap: 64,
                ..Default::default()
            },
            ks.clone(),
        );
        let got = app.run(&blobs).unwrap().outputs;
        assert_eq!(got.len(), want.len(), "{mode:?}/{shape:?}");
        for ((gi, gv), (wi, wv)) in got.iter().zip(&want) {
            assert_eq!(gi, wi);
            assert!(
                (gv - wv).abs() < 1e-2 * (1.0 + wv.abs()),
                "{mode:?}/{shape:?}: {gv} vs {wv}"
            );
        }
    }
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn taxi_app_xla_all_variants_match_reference() {
    let eng = engine();
    let ks = Rc::new(KernelSet::xla(&eng, 32).unwrap());
    let w = generate(
        8,
        TaxiGenConfig {
            avg_pairs: 5,
            avg_line_len: 150,
        },
        33,
    );
    let mut want = reference_pairs(&w);
    sort_pairs(&mut want);
    assert!(!want.is_empty());
    for variant in TaxiVariant::all() {
        let app = TaxiApp::new(
            TaxiConfig {
                width: 32,
                variant,
                data_cap: 1024,
                signal_cap: 256,
                ..Default::default()
            },
            ks.clone(),
        );
        let mut got = app.run(&w).unwrap().pairs;
        sort_pairs(&mut got);
        assert_eq!(got.len(), want.len(), "{variant:?}");
        for (g, e) in got.iter().zip(&want) {
            assert_eq!(g.tag, e.tag, "{variant:?}");
            assert!((g.x - e.x).abs() < 1e-4, "{variant:?}: {} vs {}", g.x, e.x);
            assert!((g.y - e.y).abs() < 1e-4, "{variant:?}: {} vs {}", g.y, e.y);
        }
    }
}

/// The paper's occupancy statistic, on the real backend at width 128 with
/// paper-shaped workloads: stage 1 mostly full, stage 2 mostly partial.
#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn taxi_xla_width128_occupancy_split() {
    let eng = engine();
    let ks = Rc::new(KernelSet::xla(&eng, 128).unwrap());
    let w = generate(6, TaxiGenConfig::default(), 77); // 1397 chars, 45 pairs
    let app = TaxiApp::new(
        TaxiConfig {
            width: 128,
            variant: TaxiVariant::Enumerated,
            data_cap: 8192,
            signal_cap: 1024,
            ..Default::default()
        },
        ks,
    );
    let r = app.run(&w).unwrap();
    let s1 = r.metrics.node("classify").unwrap().full_fraction();
    let s2 = r.metrics.node("parse").unwrap().full_fraction();
    assert!(s1 > 0.75, "stage1 full fraction {s1} (paper: 0.91)");
    assert!(s2 < 0.25, "stage2 full fraction {s2} (paper: 0.09)");
}
