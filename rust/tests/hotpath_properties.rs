//! Hot-path equivalence properties (minicheck).
//!
//! 1. The vectorized in-place kernels (`native::*_into` + the branchless
//!    reductions) are **bit-identical** to the retained scalar reference
//!    implementations (`native::scalar::*`) across widths 1..=256,
//!    including odd tails past the `chunks_exact` blocks, all-masked
//!    ensembles, and stale garbage in the caller-provided output slices.
//! 2. `DataQueue`'s bulk `pop_into`/`push_slice` match a per-item
//!    `VecDeque` model across ring wrap-around boundaries.

use regatta::coordinator::queue::DataQueue;
use regatta::runtime::native;
use regatta::util::minicheck::{Checker, Gen};
use std::collections::VecDeque;

/// Random ensemble width covering the chunks_exact main blocks (multiples
/// of 8), odd tails, and the degenerate width-1 case.
fn gen_width(g: &mut Gen) -> usize {
    match g.below(4) {
        0 => g.int_in(1, 8),       // tail-only
        1 => 8 * g.int_in(1, 32),  // exact blocks
        _ => g.int_in(1, 256),     // anything
    }
}

/// Mask with forced special shapes: all-active, all-masked, or random.
fn gen_mask(g: &mut Gen, w: usize) -> Vec<i32> {
    match g.below(4) {
        0 => vec![1; w],
        1 => vec![0; w], // all lanes masked off
        _ => (0..w).map(|_| if g.chance(0.6) { 1 } else { 0 }).collect(),
    }
}

fn gen_vals(g: &mut Gen, w: usize) -> Vec<f32> {
    (0..w).map(|_| g.f32_in(-100.0, 100.0)).collect()
}

fn assert_f32_bits(got: &[f32], want: &[f32], ctx: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{ctx}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!("{ctx}: lane {i}: {a} ({:#x}) vs {b} ({:#x})",
                a.to_bits(), b.to_bits()));
        }
    }
    Ok(())
}

#[test]
fn prop_filter_scale_into_matches_scalar() {
    Checker::new("filter-scale-into-bitwise").runs(300).check(|g| {
        let w = gen_width(g);
        let vals = gen_vals(g, w);
        let mask = gen_mask(g, w);
        let threshold = g.f32_in(-50.0, 50.0);
        // stale garbage in the out slices must be fully overwritten
        let mut ov = vec![123.5f32; w];
        let mut om = vec![-9i32; w];
        native::filter_scale_into(&vals, &mask, threshold, &mut ov, &mut om);
        let (sv, sm) = native::scalar::filter_scale(&vals, &mask, threshold);
        if om != sm {
            return Err(format!("mask mismatch at width {w}: {om:?} vs {sm:?}"));
        }
        assert_f32_bits(&ov, &sv, &format!("vals at width {w}"))
    });
}

#[test]
fn prop_reductions_match_scalar() {
    Checker::new("reductions-bitwise").runs(300).check(|g| {
        let w = gen_width(g);
        let vals = gen_vals(g, w);
        let mask = gen_mask(g, w);
        let threshold = g.f32_in(-50.0, 50.0);
        let (s, c) = native::masked_sum(&vals, &mask);
        let (ss, sc) = native::scalar::masked_sum(&vals, &mask);
        if s.to_bits() != ss.to_bits() || c != sc {
            return Err(format!("masked_sum at width {w}: ({s},{c}) vs ({ss},{sc})"));
        }
        let (r, k) = native::sum_region(&vals, &mask, threshold);
        let (sr, sk) = native::scalar::sum_region(&vals, &mask, threshold);
        if r.to_bits() != sr.to_bits() || k != sk {
            return Err(format!("sum_region at width {w}: ({r},{k}) vs ({sr},{sk})"));
        }
        Ok(())
    });
}

#[test]
fn prop_segmented_kernels_match_scalar() {
    Checker::new("segmented-into-bitwise").runs(300).check(|g| {
        let w = gen_width(g);
        let vals = gen_vals(g, w);
        let mask = gen_mask(g, w);
        let seg: Vec<i32> = (0..w).map(|_| g.int_in(0, w - 1) as i32).collect();
        let threshold = g.f32_in(-50.0, 50.0);

        let mut sums = vec![55.5f32; w];
        let mut counts = vec![77i32; w];
        native::segmented_sum_into(&vals, &seg, &mask, &mut sums, &mut counts);
        let (ss, sc) = native::scalar::segmented_sum(&vals, &seg, &mask);
        if counts != sc {
            return Err(format!("segmented counts at width {w}"));
        }
        assert_f32_bits(&sums, &ss, &format!("segmented sums at width {w}"))?;

        native::tagged_sum_region_into(&vals, &seg, &mask, threshold, &mut sums, &mut counts);
        let (ts, tc) = native::scalar::tagged_sum_region(&vals, &seg, &mask, threshold);
        if counts != tc {
            return Err(format!("tagged counts at width {w}"));
        }
        assert_f32_bits(&sums, &ts, &format!("tagged sums at width {w}"))
    });
}

#[test]
fn prop_char_classify_into_matches_scalar() {
    // interesting char set: digits, markers, braces, noise
    const CHARS: [i32; 12] = [
        0x30, 0x35, 0x39, 0x2E, 0x2C, 0x2D, 0x7B, 0x7D, 0x41, 0x20, 0x00, 0x7F,
    ];
    Checker::new("char-classify-into").runs(300).check(|g| {
        let w = gen_width(g);
        let chars: Vec<i32> = (0..w).map(|_| *g.choose(&CHARS)).collect();
        let mask = gen_mask(g, w);
        let mut flags = vec![-1i32; w];
        let mut bits = vec![-1i32; w];
        native::char_classify_into(&chars, &mask, &mut flags, &mut bits);
        let (sf, sb) = native::scalar::char_classify(&chars, &mask);
        if flags != sf || bits != sb {
            return Err(format!("classify mismatch at width {w}"));
        }
        Ok(())
    });
}

#[test]
fn prop_coord_parse_into_matches_scalar() {
    Checker::new("coord-parse-into").runs(150).check(|g| {
        let w = g.int_in(1, 48);
        let wl = native::WINDOW_LEN;
        let mut windows = vec![0i32; w * wl];
        for lane in 0..w {
            let win = &mut windows[lane * wl..(lane + 1) * wl];
            if g.chance(0.6) {
                // a mostly-valid `{a.b,-c.d}` pair (sometimes truncated)
                let text = format!(
                    "{{{}.{},{}{}.{}}}",
                    g.int_in(0, 500),
                    g.int_in(0, 99),
                    if g.chance(0.5) { "-" } else { "" },
                    g.int_in(0, 500),
                    g.int_in(0, 99)
                );
                let cut = if g.chance(0.15) {
                    g.int_in(1, text.len())
                } else {
                    text.len()
                };
                for (k, b) in text.bytes().take(cut.min(wl)).enumerate() {
                    win[k] = b as i32;
                }
            } else {
                for slot in win.iter_mut() {
                    *slot = g.int_in(0, 127) as i32;
                }
            }
        }
        let mask = gen_mask(g, w);
        let (mut x, mut y, mut ok) = (vec![9.0f32; w], vec![9.0f32; w], vec![9i32; w]);
        native::coord_parse_into(&windows, wl, &mask, &mut x, &mut y, &mut ok);
        let (sx, sy, sok) = native::scalar::coord_parse(&windows, wl, &mask);
        if ok != sok {
            return Err(format!("ok mismatch at width {w}"));
        }
        assert_f32_bits(&x, &sx, "x")?;
        assert_f32_bits(&y, &sy, "y")
    });
}

#[test]
fn prop_queue_bulk_ops_match_per_item_model() {
    Checker::new("queue-bulk-vs-per-item").runs(300).check(|g| {
        let cap = g.int_in(1, 48);
        let mut q: DataQueue<u32> = DataQueue::new(cap);
        let mut model: VecDeque<u32> = VecDeque::new();
        let mut next = 0u32;
        let steps = g.int_in(1, 80);
        for step in 0..steps {
            if g.chance(0.5) {
                // bulk push of a run that fits
                let n = g.int_in(0, cap - model.len());
                let items: Vec<u32> = (0..n)
                    .map(|_| {
                        next += 1;
                        next
                    })
                    .collect();
                q.push_slice(&items);
                model.extend(items.iter().copied());
            } else {
                // bulk pop vs per-item model pops
                let n = g.int_in(0, cap);
                let mut out = Vec::new();
                let got = q.pop_into(n, &mut out);
                let want: Vec<u32> = (0..n.min(model.len()))
                    .map(|_| model.pop_front().expect("model length checked"))
                    .collect();
                if got != want.len() || out != want {
                    return Err(format!(
                        "step {step}: popped {out:?} (n={got}), want {want:?}"
                    ));
                }
            }
            if q.len() != model.len() || q.space() != cap - model.len() {
                return Err(format!(
                    "step {step}: len {} vs model {}",
                    q.len(),
                    model.len()
                ));
            }
        }
        // drain the rest and confirm order
        let mut out = Vec::new();
        q.pop_into(cap, &mut out);
        let rest: Vec<u32> = model.drain(..).collect();
        if out != rest {
            return Err(format!("final drain {out:?} vs {rest:?}"));
        }
        Ok(())
    });
}
