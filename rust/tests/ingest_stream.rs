//! Streaming ingest + work stealing ≡ the materialized single-threaded
//! run — plus the bounded-memory proof.
//!
//! The v2 executor's contract (see `regatta::exec`):
//!
//! 1. **Equivalence** — streaming ingest with stealing produces output
//!    bit-identical to the materialized single-threaded run, for every
//!    worker count 1–8, across uniform and skewed region-size mixes
//!    (shard boundaries depend only on the stream prefix, the merge
//!    restores stream order, and region-local pipelines are insensitive
//!    to shard grouping).
//! 2. **Bounded ingest** — steady-state ingest allocations are governed
//!    by the in-flight budget, not stream length: 10× the regions adds
//!    no measurable driver-side allocations (counting global allocator).
//!
//! Plus the planner/plan edge cases the ISSUE calls out: empty stream,
//! one giant region, more workers than regions, steal-heavy skew.

use std::rc::Rc;

use anyhow::Result;

use regatta::apps::sum::{SumApp, SumConfig, SumMode, SumShape};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiVariant};
use regatta::exec::{
    ClaimMode, ExecConfig, PipelineFactory, ShardOutput, ShardWorker, ShardedRunner,
};
use regatta::prelude::Policy;
use regatta::runtime::kernels::KernelSet;
use regatta::util::alloc_count;
use regatta::workload::regions::{gen_blobs, GenBlobSource, RegionSpec};
use regatta::workload::source::{IterSource, SliceSource};
use regatta::workload::taxi::{generate, TaxiGenConfig};

const WIDTH: usize = 8;

fn sum_app(mode: SumMode, shape: SumShape) -> SumApp {
    SumApp::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        Rc::new(KernelSet::native(WIDTH)),
    )
}

fn region_mixes() -> Vec<(u64, RegionSpec)> {
    vec![
        (1, RegionSpec::Fixed { size: 17 }),
        (2, RegionSpec::Uniform { max: 40 }),
        (3, RegionSpec::Skewed { max: 200 }),
        (4, RegionSpec::Skewed { max: 1000 }),
    ]
}

fn assert_sums_bitwise(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for (i, ((gi, gv), (wi, wv))) in got.iter().zip(want).enumerate() {
        assert_eq!(gi, wi, "{ctx}: region id at {i}");
        assert_eq!(
            gv.to_bits(),
            wv.to_bits(),
            "{ctx}: region {gi} sum {gv} vs {wv}"
        );
    }
}

#[test]
fn streaming_sum_is_bitwise_identical_for_workers_1_to_8() {
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    for (seed, spec) in region_mixes() {
        let blobs = gen_blobs(2000, spec, seed);
        let single = app.run(&blobs).unwrap();
        for workers in 1..=8 {
            // tight budget so backpressure actually engages
            let exec = ExecConfig::new(workers).streaming(32);
            let streamed = app
                .run_streaming(GenBlobSource::new(2000, spec, seed), &exec)
                .unwrap();
            assert_sums_bitwise(
                &streamed.outputs,
                &single.outputs,
                &format!("{spec:?} seed {seed} workers {workers}"),
            );
            assert_eq!(
                streamed.invocations, single.invocations,
                "{spec:?} workers {workers}: kernel invocations"
            );
        }
    }
}

#[test]
fn streaming_without_stealing_is_also_bitwise_identical() {
    // stealing changes who runs a shard, never what the shard computes
    let app = sum_app(SumMode::Enumerated, SumShape::TwoStage);
    let blobs = gen_blobs(1500, RegionSpec::Skewed { max: 300 }, 5);
    let single = app.run(&blobs).unwrap();
    for claim in [ClaimMode::Steal, ClaimMode::NoSteal] {
        let exec = ExecConfig::new(4).streaming(64).with_claim(claim);
        let streamed = app.run_streaming(SliceSource::new(&blobs), &exec).unwrap();
        assert_sums_bitwise(&streamed.outputs, &single.outputs, claim.label());
    }
}

#[test]
fn streaming_tagged_sum_keeps_order_and_tolerance() {
    // the lane-mixing tagged baseline keeps the weaker guarantee: same
    // ids in the same order, values within float-reassociation tolerance
    let app = sum_app(SumMode::Tagged, SumShape::Fused);
    let blobs = gen_blobs(1200, RegionSpec::Fixed { size: 13 }, 21);
    let single = app.run(&blobs).unwrap();
    for workers in [1usize, 3, 8] {
        let exec = ExecConfig::new(workers).streaming(16);
        let streamed = app.run_streaming(SliceSource::new(&blobs), &exec).unwrap();
        assert_eq!(streamed.outputs.len(), single.outputs.len());
        for ((gi, gv), (wi, wv)) in streamed.outputs.iter().zip(&single.outputs) {
            assert_eq!(gi, wi, "workers {workers}: tag order");
            assert!(
                (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                "workers {workers}: tag {gi}: {gv} vs {wv}"
            );
        }
    }
}

#[test]
fn streaming_taxi_is_bitwise_identical_for_workers_1_to_8() {
    let w = generate(
        24,
        TaxiGenConfig {
            avg_pairs: 6,
            avg_line_len: 160,
        },
        77,
    );
    for variant in TaxiVariant::all() {
        let app = TaxiApp::new(
            TaxiConfig {
                width: WIDTH,
                variant,
                data_cap: 512,
                signal_cap: 128,
                policy: Policy::GreedyOccupancy,
            },
            Rc::new(KernelSet::native(WIDTH)),
        );
        let single = app.run(&w).unwrap();
        assert_eq!(single.pairs.len(), w.total_pairs, "{variant:?}: sanity");
        for workers in 1..=8 {
            let exec = ExecConfig::new(workers).streaming(8);
            let streamed = app
                .run_streaming(w.text.clone(), SliceSource::new(&w.lines), &exec)
                .unwrap();
            assert_eq!(streamed.pairs.len(), single.pairs.len());
            for (i, (g, e)) in streamed.pairs.iter().zip(&single.pairs).enumerate() {
                assert_eq!(g.tag, e.tag, "{variant:?} workers {workers}: tag at {i}");
                assert_eq!(g.x.to_bits(), e.x.to_bits(), "{variant:?} w{workers} x {i}");
                assert_eq!(g.y.to_bits(), e.y.to_bits(), "{variant:?} w{workers} y {i}");
            }
        }
    }
}

// ---- edge cases ----------------------------------------------------

#[test]
fn empty_stream_streams_cleanly() {
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let exec = ExecConfig::new(4).streaming(16);
    let report = app.run_streaming(SliceSource::new(&[]), &exec).unwrap();
    assert!(report.outputs.is_empty());
    assert_eq!(report.invocations, 0);
}

#[test]
fn one_giant_region_streams_without_splitting() {
    // one region carrying the whole stream's weight: it must travel as a
    // single shard (regions are never split) through a tiny budget, and
    // the weight rule must not deadlock the ingest loop
    let blobs = vec![regatta::prelude::Blob::from_vec(
        0,
        (0..5000).map(|i| (i % 7) as f32 / 7.0 - 0.5).collect(),
    )];
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let single = app.run(&blobs).unwrap();
    let exec = ExecConfig::new(3).streaming(4);
    let streamed = app.run_streaming(SliceSource::new(&blobs), &exec).unwrap();
    assert_sums_bitwise(&streamed.outputs, &single.outputs, "giant region");
}

#[test]
fn more_workers_than_regions_streams_cleanly() {
    let blobs = gen_blobs(10, RegionSpec::Fixed { size: 5 }, 31);
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let single = app.run(&blobs).unwrap();
    let exec = ExecConfig::new(8).streaming(128);
    let streamed = app.run_streaming(SliceSource::new(&blobs), &exec).unwrap();
    assert_sums_bitwise(&streamed.outputs, &single.outputs, "more workers");
}

// ---- bounded-ingest proof ------------------------------------------

/// Heap-free toy pipeline: regions are bare `u32`s, outputs are folded
/// into the shard's invocation counter, so every allocation observed on
/// the driver thread belongs to the ingest machinery itself.
#[cfg(feature = "count-allocs")]
struct CountFactory;

#[cfg(feature = "count-allocs")]
struct CountWorker;

#[cfg(feature = "count-allocs")]
impl ShardWorker for CountWorker {
    type In = u32;
    type Out = u32;

    fn run_shard(&mut self, shard: &[u32]) -> Result<ShardOutput<u32>> {
        Ok(ShardOutput {
            outputs: Vec::new(), // Vec::new never allocates
            metrics: Default::default(),
            invocations: shard.iter().map(|&v| v as u64).sum(),
        })
    }
}

#[cfg(feature = "count-allocs")]
impl PipelineFactory for CountFactory {
    type In = u32;
    type Out = u32;
    type Worker = CountWorker;

    fn make_worker(&self, _worker_id: usize) -> Result<CountWorker> {
        Ok(CountWorker)
    }
}

/// Run a full streaming pass and return the allocations charged to the
/// calling (ingest-driver) thread.
#[cfg(feature = "count-allocs")]
fn ingest_allocs(regions: u32, budget: usize) -> (u64, u64) {
    let runner = ShardedRunner::new(ExecConfig::new(2).streaming(budget));
    let mut folded = 0u64;
    let before = alloc_count::thread_allocations();
    let report = runner
        .run_stream_with(&CountFactory, IterSource::new(0..regions), |r| {
            folded += r.invocations;
            Ok(())
        })
        .unwrap();
    let allocs = alloc_count::thread_allocations() - before;
    assert_eq!(folded, (0..regions as u64).sum::<u64>());
    assert!(report.shards > 0);
    (allocs, report.shards as u64)
}

#[test]
#[cfg(feature = "count-allocs")]
fn ingest_allocations_are_bounded_by_the_budget_not_stream_length() {
    let budget = 64;
    // warm the process-level pools (thread stacks etc.) once
    let _ = ingest_allocs(2_000, budget);
    let (small, small_shards) = ingest_allocs(2_000, budget);
    let (large, large_shards) = ingest_allocs(20_000, budget);
    assert!(
        large_shards >= 10 * small_shards - 10,
        "sanity: the large run really has ~10x the shards ({small_shards} vs {large_shards})"
    );
    // 10x the regions and shards must not add measurable ingest
    // allocations: container recycling + the pre-sized reassembly ring
    // make the steady-state loop allocation-free. The slack absorbs
    // scheduling-dependent growth of the bounded queues, nothing else —
    // a per-shard leak would cost thousands of allocations here.
    assert!(
        large <= small + 64,
        "ingest allocations scale with stream length: {small} allocs for \
         {small_shards} shards vs {large} for {large_shards}"
    );
}
