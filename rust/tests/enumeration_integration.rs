//! Enumeration/aggregation integration: the paper's §4 abstraction over
//! richer composites and nesting-adjacent scenarios.

use std::rc::Rc;

use regatta::coordinator::aggregate::{Aggregator, FilterMapLogic};
use regatta::coordinator::enumerate::{Blob, Composite};
use regatta::coordinator::node::Emitter;
use regatta::coordinator::signal::parent_as;
use regatta::coordinator::topology::PipelineBuilder;

/// A graph vertex with its adjacency list — the intro's "stream of edges
/// grouped by their source vertex".
#[derive(Debug, Clone)]
struct Vertex {
    id: u64,
    edges: Vec<(u64, f32)>, // (dst, weight)
}

impl Composite for Vertex {
    fn count(&self) -> usize {
        self.edges.len()
    }
}

#[test]
fn custom_composites_enumerate_like_blobs() {
    let mut b = PipelineBuilder::new(4).queue_caps(64, 32);
    let src = b.source::<Vertex>();
    let elems = b.enumerate("edges", &src);
    let degrees = b.sink(
        "degree",
        &elems,
        Aggregator::new(
            (0u64, 0.0f64),
            |acc: &mut (u64, f64), idxs: &[u32], parent| {
                let v = parent_as::<Vertex>(parent.unwrap()).unwrap();
                acc.0 += idxs.len() as u64;
                acc.1 += idxs.iter().map(|&i| v.edges[i as usize].1 as f64).sum::<f64>();
                Ok(())
            },
            |acc: &mut (u64, f64), p| {
                let v = parent_as::<Vertex>(p).unwrap();
                Ok(Some((v.id, acc.0, acc.1)))
            },
        ),
    );
    src.push(Vertex {
        id: 0,
        edges: vec![(1, 0.5), (2, 1.5)],
    });
    src.push(Vertex { id: 1, edges: vec![] });
    src.push(Vertex {
        id: 2,
        edges: vec![(0, 2.0), (1, 3.0), (3, 4.0), (4, 5.0), (5, 6.0)],
    });
    let mut pipe = b.build();
    pipe.run().unwrap();
    let got = degrees.borrow().clone();
    assert_eq!(got[0], (0, 2, 2.0));
    assert_eq!(got[1], (1, 0, 0.0));
    assert_eq!(got[2], (2, 5, 20.0));
}

/// Sequential re-enumeration: aggregate closes the first region scope;
/// a second enumerator downstream opens a new one (the legal alternative
/// to nesting, which is rejected).
#[test]
fn aggregate_then_reenumerate() {
    let mut b = PipelineBuilder::new(4).queue_caps(64, 32);
    let src = b.source::<Blob>();
    let elems = b.enumerate("enum1", &src);
    // aggregate: per blob, a new blob holding the doubled elements —
    // composite-to-composite
    let rebuilt = b.node(
        "rebuild",
        &elems,
        Aggregator::new(
            Vec::<f32>::new(),
            |acc: &mut Vec<f32>, idxs: &[u32], parent| {
                let blob = parent_as::<Blob>(parent.unwrap()).unwrap();
                acc.extend(idxs.iter().map(|&i| 2.0 * blob.get(i)));
                Ok(())
            },
            |acc: &mut Vec<f32>, p| {
                let blob = parent_as::<Blob>(p).unwrap();
                Ok(Some(Blob::from_vec(blob.id + 100, std::mem::take(acc))))
            },
        ),
    );
    let elems2 = b.enumerate("enum2", &rebuilt);
    let sums = b.sink(
        "sum",
        &elems2,
        Aggregator::new(
            0.0f64,
            |acc: &mut f64, idxs: &[u32], parent| {
                let blob = parent_as::<Blob>(parent.unwrap()).unwrap();
                *acc += idxs.iter().map(|&i| blob.get(i) as f64).sum::<f64>();
                Ok(())
            },
            |acc: &mut f64, p| {
                let blob = parent_as::<Blob>(p).unwrap();
                Ok(Some((blob.id, *acc)))
            },
        ),
    );
    src.push(Blob::from_vec(0, vec![1.0, 2.0, 3.0]));
    src.push(Blob::from_vec(1, vec![10.0]));
    let mut pipe = b.build();
    pipe.run().unwrap();
    let got = sums.borrow().clone();
    assert_eq!(got, vec![(100, 12.0), (101, 20.0)]);
}

/// Nested enumeration is rejected loudly, not silently mis-executed.
#[test]
fn nested_enumeration_is_rejected() {
    #[derive(Debug, Clone)]
    struct Outer(Vec<Blob>);
    impl Composite for Outer {
        fn count(&self) -> usize {
            self.0.len()
        }
    }
    let mut b = PipelineBuilder::new(4).queue_caps(64, 32);
    let src = b.source::<Outer>();
    let outer_elems = b.enumerate("outer", &src);
    // a node that converts outer indices back into Blobs IN-REGION
    // (forwarding region signals), feeding a second enumerator: illegal
    let inner_blobs = b.node(
        "to_blob",
        &outer_elems,
        FilterMapLogic::new(1, |idxs: &[u32], parent, out: &mut Emitter<'_, Blob>| {
            let outer = parent_as::<Outer>(parent.unwrap()).unwrap();
            for &i in idxs {
                out.push(outer.0[i as usize].clone());
            }
            Ok(())
        }),
    );
    let inner_elems = b.enumerate("inner", &inner_blobs);
    let _sink = b.sink(
        "sum",
        &inner_elems,
        Aggregator::new(
            0u64,
            |acc: &mut u64, items: &[u32], _| {
                *acc += items.len() as u64;
                Ok(())
            },
            |acc: &mut u64, _| Ok(Some(*acc)),
        ),
    );
    src.push(Outer(vec![Blob::from_vec(0, vec![1.0])]));
    let mut pipe = b.build();
    let err = pipe.run().unwrap_err();
    assert!(
        err.to_string().contains("nested enumeration"),
        "unexpected error: {err}"
    );
}

/// Region context with zero-element and single-element extremes mixed in
/// one stream, at width 1 (fully serialized SIMD degenerate case).
#[test]
fn degenerate_widths_and_regions() {
    let mut b = PipelineBuilder::new(1).queue_caps(8, 8);
    let src = b.source::<Blob>();
    let elems = b.enumerate("enum", &src);
    let counts = b.sink(
        "n",
        &elems,
        Aggregator::new(
            0u64,
            |acc: &mut u64, items: &[u32], _| {
                *acc += items.len() as u64;
                Ok(())
            },
            |acc: &mut u64, _| Ok(Some(*acc)),
        ),
    );
    for (id, size) in [(0u64, 0usize), (1, 1), (2, 0), (3, 5), (4, 0)] {
        src.push(Blob::from_vec(id, vec![1.0; size]));
    }
    let mut pipe = b.build();
    pipe.run().unwrap();
    assert_eq!(*counts.borrow(), vec![0, 1, 0, 5, 0]);
    // width 1: every non-empty ensemble is "full"
    let m = pipe.metrics();
    assert_eq!(m.node("n").unwrap().full_fraction(), 1.0);
}

/// Tree topology (paper Fig. 1b): enumerate, broadcast the element stream
/// to two differently-behaving children, aggregate each — both children
/// observe the same precise region boundaries.
#[test]
fn tree_topology_broadcast_preserves_regions() {
    let mut b = PipelineBuilder::new(4).queue_caps(64, 32);
    let src = b.source::<Blob>();
    let elems = b.enumerate("enum", &src);
    let kids = b.broadcast("tee", &elems, 2);

    // child A: per-blob element count
    let counts = b.sink(
        "count",
        &kids[0],
        Aggregator::new(
            0u64,
            |acc: &mut u64, items: &[u32], _| {
                *acc += items.len() as u64;
                Ok(())
            },
            |acc: &mut u64, p| {
                let blob = parent_as::<Blob>(p).unwrap();
                Ok(Some((blob.id, *acc)))
            },
        ),
    );
    // child B: per-blob sum of values (uses the parent through its copy
    // of the region signals)
    let sums = b.sink(
        "sum",
        &kids[1],
        Aggregator::new(
            0.0f64,
            |acc: &mut f64, idxs: &[u32], parent| {
                let blob = parent_as::<Blob>(parent.unwrap()).unwrap();
                *acc += idxs.iter().map(|&i| blob.get(i) as f64).sum::<f64>();
                Ok(())
            },
            |acc: &mut f64, p| {
                let blob = parent_as::<Blob>(p).unwrap();
                Ok(Some((blob.id, *acc)))
            },
        ),
    );

    src.push(Blob::from_vec(0, vec![1.0, 2.0, 3.0]));
    src.push(Blob::from_vec(1, vec![]));
    src.push(Blob::from_vec(2, (0..11).map(|i| i as f32).collect()));
    let mut pipe = b.build();
    pipe.run().unwrap();

    assert_eq!(*counts.borrow(), vec![(0, 3), (1, 0), (2, 11)]);
    let s = sums.borrow().clone();
    assert_eq!(s.len(), 3);
    assert!((s[0].1 - 6.0).abs() < 1e-9);
    assert_eq!(s[1], (1, 0.0));
    assert!((s[2].1 - 55.0).abs() < 1e-9);
}
