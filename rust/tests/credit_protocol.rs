//! Credit-protocol integration tests: the paper's §3 rules and Lemma 1
//! (precise delivery), exercised through real channels and nodes.

use std::cell::RefCell;
use std::rc::Rc;

use regatta::coordinator::channel::Channel;
use regatta::coordinator::node::{Emitter, Node, NodeLogic, NodeOps, Output};
use regatta::coordinator::signal::{ParentRef, SignalKind};

/// Records, per received custom signal, how many data items had been
/// consumed at that moment — the observable Lemma 1 quantifies over.
#[derive(Default)]
struct DeliveryRecorder {
    consumed: u64,
    deliveries: Vec<(u64, u64)>, // (signal id, items consumed when received)
}

struct RecorderLogic {
    state: Rc<RefCell<DeliveryRecorder>>,
}

impl NodeLogic for RecorderLogic {
    type In = u64;
    type Out = u64;

    fn run(
        &mut self,
        items: &[u64],
        _parent: Option<&ParentRef>,
        out: &mut Emitter<'_, u64>,
    ) -> anyhow::Result<()> {
        let mut st = self.state.borrow_mut();
        st.consumed += items.len() as u64;
        for &i in items {
            out.push(i);
        }
        Ok(())
    }

    fn on_custom(&mut self, id: u64, _out: &mut Emitter<'_, u64>) -> anyhow::Result<()> {
        let mut st = self.state.borrow_mut();
        let consumed = st.consumed;
        st.deliveries.push((id, consumed));
        Ok(())
    }

    fn forward_region_signals(&self) -> bool {
        false
    }
}

fn recorder_node(
    ch: Rc<Channel<u64>>,
    width: usize,
) -> (Node<RecorderLogic>, Rc<RefCell<DeliveryRecorder>>) {
    let state = Rc::new(RefCell::new(DeliveryRecorder::default()));
    let sink = Rc::new(RefCell::new(Vec::new()));
    let node = Node::new(
        "recorder",
        width,
        ch,
        Output::Sink(sink),
        RecorderLogic {
            state: state.clone(),
        },
    );
    (node, state)
}

/// Lemma 1, deterministic trace: a signal emitted after k data items is
/// received exactly when k items have been consumed.
#[test]
fn lemma1_simple_trace() {
    let ch: Rc<Channel<u64>> = Channel::new(1024, 64);
    for i in 0..5 {
        ch.push(i);
    }
    ch.emit_signal(SignalKind::Custom(100)); // after 5 items
    for i in 5..8 {
        ch.push(i);
    }
    ch.emit_signal(SignalKind::Custom(101)); // after 8 items
    ch.emit_signal(SignalKind::Custom(102)); // also after 8 items
    for i in 8..10 {
        ch.push(i);
    }

    let (mut node, state) = recorder_node(ch, 4);
    while node.fireable() {
        node.fire().unwrap();
    }
    let st = state.borrow();
    assert_eq!(st.consumed, 10);
    assert_eq!(
        st.deliveries,
        vec![(100, 5), (101, 8), (102, 8)],
        "signals must be delivered at their precise stream positions"
    );
}

/// Lemma 1 with interleaved production and consumption: emit/consume in
/// random interleavings, verifying precision every time.
#[test]
fn lemma1_interleaved_production() {
    use regatta::util::prng::Prng;
    for seed in 0..50u64 {
        let mut rng = Prng::new(seed);
        let ch: Rc<Channel<u64>> = Channel::new(4096, 512);
        let width = 1 + rng.below(9);
        let (mut node, state) = recorder_node(ch.clone(), width);

        let mut emitted = 0u64;
        let mut expected = Vec::new();
        let mut sig_id = 0u64;
        for _step in 0..200 {
            match rng.below(3) {
                0 => {
                    // emit a burst of data
                    for _ in 0..rng.below(7) {
                        if ch.data_space() > 0 {
                            ch.push(emitted);
                            emitted += 1;
                        }
                    }
                }
                1 => {
                    // emit a signal: must be received after `emitted` items
                    if ch.signal_space() > 0 {
                        ch.emit_signal(SignalKind::Custom(sig_id));
                        expected.push((sig_id, emitted));
                        sig_id += 1;
                    }
                }
                _ => {
                    // let the receiver make some progress
                    for _ in 0..rng.below(4) {
                        if node.fireable() {
                            node.fire().unwrap();
                        }
                    }
                }
            }
        }
        while node.fireable() {
            node.fire().unwrap();
        }
        let st = state.borrow();
        assert_eq!(st.consumed, emitted, "seed {seed}");
        assert_eq!(st.deliveries, expected, "seed {seed}");
    }
}

/// §3.3 SIMD rule: no ensemble may span a signal — equivalently, every
/// ensemble's items were all emitted between the same pair of signals.
#[test]
fn ensembles_never_span_signals() {
    // map: item value -> epoch assigned at emission
    let ch: Rc<Channel<u64>> = Channel::new(1024, 64);
    let mut epochs = Vec::new();
    let mut epoch = 0u64;
    let mut next = 0u64;
    use regatta::util::prng::Prng;
    let mut rng = Prng::new(9);
    for _ in 0..30 {
        for _ in 0..rng.below(10) {
            ch.push(next);
            epochs.push(epoch);
            next += 1;
        }
        ch.emit_signal(SignalKind::Custom(epoch));
        epoch += 1;
    }

    struct EnsembleEpochs {
        epochs: Vec<u64>,
        batches: Vec<Vec<u64>>,
    }
    struct Logic {
        st: Rc<RefCell<EnsembleEpochs>>,
    }
    impl NodeLogic for Logic {
        type In = u64;
        type Out = u64;
        fn run(
            &mut self,
            items: &[u64],
            _p: Option<&ParentRef>,
            _o: &mut Emitter<'_, u64>,
        ) -> anyhow::Result<()> {
            let st = self.st.borrow();
            let batch: Vec<u64> = items.iter().map(|&i| st.epochs[i as usize]).collect();
            drop(st);
            self.st.borrow_mut().batches.push(batch);
            Ok(())
        }
        fn max_outputs_per_input(&self) -> usize {
            0
        }
        fn forward_region_signals(&self) -> bool {
            false
        }
    }

    let st = Rc::new(RefCell::new(EnsembleEpochs {
        epochs,
        batches: Vec::new(),
    }));
    let sink: Rc<RefCell<Vec<u64>>> = Rc::new(RefCell::new(Vec::new()));
    let mut node = Node::new("chk", 4, ch, Output::Sink(sink), Logic { st: st.clone() });
    while node.fireable() {
        node.fire().unwrap();
    }
    let st = st.borrow();
    assert!(!st.batches.is_empty());
    for batch in &st.batches {
        assert!(
            batch.windows(2).all(|w| w[0] == w[1]),
            "ensemble mixed epochs: {batch:?}"
        );
    }
}

/// Credit arithmetic across a chain of nodes: forwarded signals are
/// re-credited per hop and stay precise two hops downstream.
#[test]
fn precision_is_preserved_across_hops() {
    let ch0: Rc<Channel<u64>> = Channel::new(1024, 64);
    // pattern: 3 items, signal, 2 items, signal, 4 items
    for i in 0..3 {
        ch0.push(i);
    }
    ch0.emit_signal(SignalKind::Custom(0));
    for i in 3..5 {
        ch0.push(i);
    }
    ch0.emit_signal(SignalKind::Custom(1));
    for i in 5..9 {
        ch0.push(i);
    }

    // middle node: pass-through that FORWARDS signals
    struct Fwd;
    impl NodeLogic for Fwd {
        type In = u64;
        type Out = u64;
        fn run(
            &mut self,
            items: &[u64],
            _p: Option<&ParentRef>,
            out: &mut Emitter<'_, u64>,
        ) -> anyhow::Result<()> {
            for &i in items {
                out.push(i);
            }
            Ok(())
        }
    }
    let ch1: Rc<Channel<u64>> = Channel::new(4, 4); // tight queues
    let mut mid = Node::new("mid", 3, ch0, Output::Chan(ch1.clone()), Fwd);
    let (mut last, state) = recorder_node(ch1, 2);

    // drive both nodes in an arbitrary interleaving
    let mut progress = true;
    while progress {
        progress = false;
        if mid.fireable() {
            mid.fire().unwrap();
            progress = true;
        }
        if last.fireable() {
            last.fire().unwrap();
            progress = true;
        }
    }
    let st = state.borrow();
    assert_eq!(st.consumed, 9);
    assert_eq!(st.deliveries, vec![(0, 3), (1, 5)]);
}
