//! Pipeline integration: multi-stage topologies, scheduling policies,
//! metrics plumbing, and the multi-worker SIMD machine.

use std::rc::Rc;
use std::sync::Mutex;

use regatta::coordinator::aggregate::{Aggregator, FilterMapLogic, MapLogic};
use regatta::coordinator::enumerate::Blob;
use regatta::coordinator::scheduler::Policy;
use regatta::coordinator::signal::parent_as;
use regatta::coordinator::topology::PipelineBuilder;
use regatta::coordinator::node::Emitter;
use regatta::runtime::kernels::KernelSet;
use regatta::simd::{ChunkSource, SimdConfig, SimdMachine};
use regatta::workload::regions::{chunk_blobs, gen_blobs, RegionSpec};

/// Four-stage pipeline with two pass-through nodes inside the region
/// scope: parent context and signals survive multiple hops.
#[test]
fn long_pipeline_preserves_region_context() {
    let mut b = PipelineBuilder::new(4).queue_caps(64, 32);
    let src = b.source::<Blob>();
    let elems = b.enumerate("enum", &src);
    let s1 = b.node(
        "gather",
        &elems,
        FilterMapLogic::new(1, |idxs: &[u32], parent, out: &mut Emitter<'_, f32>| {
            let blob = parent_as::<Blob>(parent.unwrap()).unwrap();
            for &i in idxs {
                out.push(blob.get(i));
            }
            Ok(())
        }),
    );
    let s2 = b.node(
        "scale",
        &s1,
        FilterMapLogic::new(1, |vals: &[f32], parent, out: &mut Emitter<'_, f32>| {
            // parent must still be visible two hops below the enumerator
            anyhow::ensure!(parent.is_some(), "lost region context");
            for &v in vals {
                out.push(2.0 * v);
            }
            Ok(())
        }),
    );
    let sums = b.sink(
        "agg",
        &s2,
        Aggregator::new(
            0.0f64,
            |acc: &mut f64, items: &[f32], _| {
                *acc += items.iter().map(|&v| v as f64).sum::<f64>();
                Ok(())
            },
            |acc: &mut f64, p| {
                let blob = parent_as::<Blob>(p).unwrap();
                Ok(Some((blob.id, *acc)))
            },
        ),
    );
    for id in 0..5u64 {
        src.push(Blob::from_vec(id, vec![1.0; 7]));
    }
    let mut pipe = b.build();
    pipe.run().unwrap();
    let got = sums.borrow().clone();
    assert_eq!(got.len(), 5);
    for (id, s) in got {
        assert!((s - 14.0).abs() < 1e-9, "region {id}: {s}");
    }
}

/// Metrics: firing counts, items, occupancy and the table renderer.
#[test]
fn metrics_accounting_is_consistent() {
    let blobs = gen_blobs(500, RegionSpec::Fixed { size: 10 }, 3);
    let mut b = PipelineBuilder::new(4).queue_caps(128, 64);
    let src = b.source_with_cap::<Blob>(blobs.len());
    let elems = b.enumerate("enum", &src);
    let _sink = b.sink(
        "count",
        &elems,
        Aggregator::new(
            0u64,
            |acc: &mut u64, items: &[u32], _| {
                *acc += items.len() as u64;
                Ok(())
            },
            |acc: &mut u64, _| Ok(Some(*acc)),
        ),
    );
    for blob in &blobs {
        src.push(blob.clone());
    }
    let mut pipe = b.build();
    pipe.run().unwrap();
    let m = pipe.metrics();
    let count = m.node("count").unwrap();
    assert_eq!(count.items, 500);
    // 10 elements per region at width 4 → 3 ensembles per region (4+4+2)
    assert_eq!(count.ensembles, 150);
    assert_eq!(count.full_ensembles, 100);
    assert_eq!(count.ensemble_hist[4], 100);
    assert_eq!(count.ensemble_hist[2], 50);
    assert!((count.occupancy() - 500.0 / 600.0).abs() < 1e-9);
    assert_eq!(count.signals_consumed, 100); // Begin+End per region
    let table = m.table();
    assert!(table.contains("count") && table.contains("enum"));
    assert!(m.elapsed > 0.0);
}

/// PipelineMetrics::merge combines runs (the multi-worker path).
#[test]
fn metrics_merge_across_runs() {
    let run_once = |n: usize| {
        let blobs = gen_blobs(n, RegionSpec::Fixed { size: 8 }, 1);
        let mut b = PipelineBuilder::new(4).queue_caps(64, 32);
        let src = b.source_with_cap::<Blob>(blobs.len());
        let elems = b.enumerate("enum", &src);
        let _s = b.sink(
            "count",
            &elems,
            Aggregator::new(
                0u64,
                |acc: &mut u64, items: &[u32], _| {
                    *acc += items.len() as u64;
                    Ok(())
                },
                |acc: &mut u64, _| Ok(Some(*acc)),
            ),
        );
        for blob in &blobs {
            src.push(blob.clone());
        }
        let mut pipe = b.build();
        pipe.run().unwrap();
        pipe.metrics()
    };
    let mut total = regatta::coordinator::metrics::PipelineMetrics::default();
    total.merge(&run_once(100));
    total.merge(&run_once(60));
    assert_eq!(total.node("count").unwrap().items, 160);
}

/// The SIMD machine: N workers, each with its own pipeline instance,
/// competing for blob chunks; results merge to the sequential answer.
#[test]
fn multi_worker_machine_matches_single_worker() {
    let blobs = gen_blobs(4000, RegionSpec::Uniform { max: 50 }, 11);
    let expected = regatta::apps::sum::reference_sums(&blobs, 0.0);
    let chunks = chunk_blobs(blobs, 500);
    let source = ChunkSource::new(chunks);
    let machine = SimdMachine::new(SimdConfig {
        width: 8,
        workers: 4,
    });
    let all: Mutex<Vec<(u64, f64)>> = Mutex::new(Vec::new());
    machine
        .run(source, |_wid, src| {
            // per-worker pipeline instance (native backend: thread-safe
            // test without artifacts)
            let app = regatta::apps::sum::SumApp::new(
                regatta::apps::sum::SumConfig {
                    width: 8,
                    data_cap: 256,
                    signal_cap: 64,
                    ..Default::default()
                },
                Rc::new(KernelSet::native(8)),
            );
            while let Some(chunk) = src.claim() {
                let report = app.run(chunk).map_err(|e| anyhow::anyhow!("{e}"))?;
                all.lock().unwrap().extend(report.outputs);
            }
            Ok(())
        })
        .unwrap();
    let mut got = all.into_inner().unwrap();
    got.sort_by_key(|&(id, _)| id);
    assert_eq!(got.len(), expected.len());
    for ((gi, gv), (wi, wv)) in got.iter().zip(&expected) {
        assert_eq!(gi, wi);
        assert!((gv - wv).abs() < 1e-3 * (1.0 + wv.abs()));
    }
}

/// Scheduling-policy occupancy ordering: greedy ≥ deepest-first on a
/// workload where accumulation matters (irregular filter stage).
#[test]
fn greedy_policy_improves_downstream_occupancy() {
    let run = |policy: Policy| {
        let blobs = gen_blobs(3000, RegionSpec::Fixed { size: 100 }, 5);
        let mut b = PipelineBuilder::new(16).queue_caps(512, 128).policy(policy);
        let src = b.source_with_cap::<Blob>(blobs.len());
        let elems = b.enumerate("enum", &src);
        // irregular filter: ~1/3 survive, region signals ABSORBED so the
        // downstream stage may accumulate across regions
        let survivors = b.node(
            "filter",
            &elems,
            NoForwardFilter,
        );
        let _sink = b.sink("downstream", &survivors, MapLogic::new(|&v: &u32| v));
        for blob in &blobs {
            src.push(blob.clone());
        }
        let mut pipe = b.build();
        pipe.run().unwrap();
        pipe.metrics().node("downstream").unwrap().occupancy()
    };
    let greedy = run(Policy::GreedyOccupancy);
    let deepest = run(Policy::DeepestFirst);
    assert!(
        greedy > deepest,
        "greedy {greedy} should beat deepest-first {deepest}"
    );
    assert!(greedy > 0.9, "greedy occupancy {greedy}");
}

struct NoForwardFilter;
impl regatta::coordinator::node::NodeLogic for NoForwardFilter {
    type In = u32;
    type Out = u32;
    fn run(
        &mut self,
        items: &[u32],
        _p: Option<&regatta::coordinator::signal::ParentRef>,
        out: &mut Emitter<'_, u32>,
    ) -> anyhow::Result<()> {
        for &i in items {
            if i % 3 == 0 {
                out.push(i);
            }
        }
        Ok(())
    }
    fn forward_region_signals(&self) -> bool {
        false
    }
}
