//! Out-of-core round-trip equivalence: `.rgn` files, taxi text files and
//! streaming result sinks against the in-memory oracles.
//!
//! The io subsystem's contract (see `regatta::io`):
//!
//! 1. **Round-trip bit-identity** — `BlobWriter(GenBlobSource)` →
//!    `BlobFileSource` reproduces the generator's blob sequence exactly,
//!    and a file-backed streaming run is bit-identical to the
//!    materialized single-threaded run for workers 1–8, across uniform
//!    and skewed region mixes (same for taxi text files).
//! 2. **Named failures** — corrupted frames, truncated containers and
//!    malformed text records surface as named `run_stream*` errors via
//!    `RegionSource::close`, never as panics or silently short output.
//! 3. **Stream-order sinks** — `run_streaming_into` + JSONL/binary sink
//!    produces byte-identical files to rendering the materialized run's
//!    outputs, for both apps.
//!
//! Plus the satellite validations: `--ingest-buffer 0` (and absurd
//! budgets) are named `ExecConfig::validate` errors through every app
//! entry point.

use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use regatta::apps::sum::{SumApp, SumConfig, SumMode, SumShape};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiPair, TaxiVariant};
use regatta::exec::ExecConfig;
use regatta::io::{
    peek_rgn_footer, read_rgn_file, write_rgn_file, write_taxi_file, BinarySink,
    BlobFileSource, JsonRecord, JsonlSink, ResultSink, TextSource,
};
use regatta::prelude::Policy;
use regatta::runtime::kernels::KernelSet;
use regatta::workload::regions::{gen_blobs, GenBlobSource, RegionSpec};
use regatta::workload::taxi::{generate, TaxiGenConfig};

const WIDTH: usize = 8;

/// Unique self-deleting temp file per test.
struct TempFile(PathBuf);

impl TempFile {
    fn new(name: &str) -> TempFile {
        TempFile(std::env::temp_dir().join(format!(
            "regatta_test_{}_{name}",
            std::process::id()
        )))
    }
}

impl Drop for TempFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn sum_app(mode: SumMode, shape: SumShape) -> SumApp {
    SumApp::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        Rc::new(KernelSet::native(WIDTH)),
    )
}

fn taxi_app(variant: TaxiVariant) -> TaxiApp {
    TaxiApp::new(
        TaxiConfig {
            width: WIDTH,
            variant,
            data_cap: 512,
            signal_cap: 128,
            policy: Policy::GreedyOccupancy,
        },
        Rc::new(KernelSet::native(WIDTH)),
    )
}

fn assert_sums_bitwise(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for (i, ((gi, gv), (wi, wv))) in got.iter().zip(want).enumerate() {
        assert_eq!(gi, wi, "{ctx}: region id at {i}");
        assert_eq!(gv.to_bits(), wv.to_bits(), "{ctx}: region {gi}");
    }
}

// ---- .rgn round trips ----------------------------------------------

#[test]
fn rgn_file_reproduces_the_generator_exactly() {
    for (name, spec, seed) in [
        ("uniform", RegionSpec::Uniform { max: 40 }, 5u64),
        ("skewed", RegionSpec::Skewed { max: 400 }, 6),
    ] {
        let want = gen_blobs(3000, spec, seed);
        let tmp = TempFile::new(&format!("roundtrip_{name}.rgn"));
        let stats = write_rgn_file(&tmp.0, GenBlobSource::new(3000, spec, seed)).unwrap();
        assert_eq!(stats.regions as usize, want.len(), "{name}");
        assert_eq!(stats.items, 3000, "{name}");
        let footer = peek_rgn_footer(&tmp.0).unwrap();
        assert_eq!(footer.regions as usize, want.len(), "{name}");
        assert_eq!(footer.items, 3000, "{name}");
        let got = read_rgn_file(&tmp.0).unwrap();
        assert_eq!(got, want, "{name}: bit-identical blob sequence");
    }
}

#[test]
fn file_backed_sum_is_bitwise_identical_for_workers_1_to_8() {
    for (name, spec, seed) in [
        ("uniform", RegionSpec::Uniform { max: 40 }, 2u64),
        ("skewed", RegionSpec::Skewed { max: 300 }, 3),
    ] {
        let blobs = gen_blobs(2000, spec, seed);
        let tmp = TempFile::new(&format!("exec_{name}.rgn"));
        write_rgn_file(&tmp.0, GenBlobSource::new(2000, spec, seed)).unwrap();
        let app = sum_app(SumMode::Enumerated, SumShape::Fused);
        let single = app.run(&blobs).unwrap();
        for workers in 1..=8 {
            // tight budget so backpressure engages on the file reader
            let exec = ExecConfig::new(workers).streaming(32);
            let streamed = app
                .run_streaming(BlobFileSource::open(&tmp.0).unwrap(), &exec)
                .unwrap();
            assert_sums_bitwise(
                &streamed.outputs,
                &single.outputs,
                &format!("{name} workers {workers}"),
            );
            assert_eq!(
                streamed.invocations, single.invocations,
                "{name} workers {workers}: kernel invocations"
            );
        }
    }
}

#[test]
fn file_backed_two_stage_also_round_trips() {
    let blobs = gen_blobs(800, RegionSpec::Uniform { max: 24 }, 9);
    let tmp = TempFile::new("two_stage.rgn");
    write_rgn_file(&tmp.0, GenBlobSource::new(800, RegionSpec::Uniform { max: 24 }, 9)).unwrap();
    let app = sum_app(SumMode::Enumerated, SumShape::TwoStage);
    let single = app.run(&blobs).unwrap();
    let exec = ExecConfig::new(3).streaming(16);
    let streamed = app
        .run_streaming(BlobFileSource::open(&tmp.0).unwrap(), &exec)
        .unwrap();
    assert_sums_bitwise(&streamed.outputs, &single.outputs, "two-stage");
}

// ---- named failures through the executor ---------------------------

#[test]
fn corrupted_frame_aborts_the_streaming_run_with_a_named_error() {
    let tmp = TempFile::new("corrupt.rgn");
    write_rgn_file(&tmp.0, GenBlobSource::new(500, RegionSpec::Fixed { size: 16 }, 4)).unwrap();
    let mut bytes = std::fs::read(&tmp.0).unwrap();
    // header 16 | frame0: len@16 checksum@20 payload@28.. — byte 40 sits
    // inside frame 0's payload, so the checksum must catch the flip
    bytes[40] ^= 0x01;
    std::fs::write(&tmp.0, &bytes).unwrap();
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let exec = ExecConfig::new(3).streaming(8);
    let err = app
        .run_streaming(BlobFileSource::open(&tmp.0).unwrap(), &exec)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("corrupted frame"), "{msg}");
}

#[test]
fn truncated_file_aborts_the_streaming_run_with_a_named_error() {
    let tmp = TempFile::new("truncated.rgn");
    write_rgn_file(&tmp.0, GenBlobSource::new(500, RegionSpec::Fixed { size: 16 }, 4)).unwrap();
    let bytes = std::fs::read(&tmp.0).unwrap();
    std::fs::write(&tmp.0, &bytes[..bytes.len() * 2 / 3]).unwrap();
    // the footer peek already names the truncation…
    let err = peek_rgn_footer(&tmp.0).unwrap_err();
    assert!(format!("{err:#}").contains("missing .rgn footer"), "{err:#}");
    // …and so does the streaming run itself
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let exec = ExecConfig::new(2).streaming(8);
    let err = app
        .run_streaming(BlobFileSource::open(&tmp.0).unwrap(), &exec)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("truncated"), "{msg}");
}

#[test]
fn peek_footer_names_wrong_format_files() {
    let tmp = TempFile::new("not_rgn.bin");
    std::fs::write(&tmp.0, vec![0u8; 128]).unwrap();
    let err = peek_rgn_footer(&tmp.0).unwrap_err();
    assert!(err.to_string().contains("not a .rgn container"), "{err}");
}

#[test]
fn malformed_taxi_text_aborts_the_streaming_run_with_a_named_error() {
    let tmp = TempFile::new("malformed.txt");
    std::fs::write(&tmp.0, b"T0,{1.0,2.0},ok\nnot-a-record\n").unwrap();
    let app = taxi_app(TaxiVariant::Hybrid);
    let source = TextSource::open(&tmp.0).unwrap();
    let text = source.text();
    let exec = ExecConfig::new(2).streaming(8);
    let err = app.run_streaming(text, source, &exec).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("malformed taxi record"), "{msg}");
    assert!(msg.contains("line 2"), "{msg}");
}

// ---- taxi text round trip ------------------------------------------

#[test]
fn file_backed_taxi_is_bitwise_identical_for_workers_1_to_8() {
    let w = generate(
        24,
        TaxiGenConfig {
            avg_pairs: 6,
            avg_line_len: 160,
        },
        77,
    );
    let tmp = TempFile::new("taxi.txt");
    write_taxi_file(&tmp.0, &w.text, 1).unwrap();
    for variant in TaxiVariant::all() {
        let app = taxi_app(variant);
        let single = app.run(&w).unwrap();
        assert_eq!(single.pairs.len(), w.total_pairs, "{variant:?}: sanity");
        for workers in [1usize, 3, 8] {
            let source = TextSource::open(&tmp.0).unwrap();
            let text = source.text();
            let exec = ExecConfig::new(workers).streaming(8);
            let streamed = app.run_streaming(text, source, &exec).unwrap();
            assert_eq!(streamed.pairs.len(), single.pairs.len());
            for (i, (g, e)) in streamed.pairs.iter().zip(&single.pairs).enumerate() {
                assert_eq!(g.tag, e.tag, "{variant:?} w{workers}: tag at {i}");
                assert_eq!(g.x.to_bits(), e.x.to_bits(), "{variant:?} w{workers} x {i}");
                assert_eq!(g.y.to_bits(), e.y.to_bits(), "{variant:?} w{workers} y {i}");
            }
        }
    }
}

#[test]
fn replicated_taxi_file_matches_replicated_workload() {
    let base = generate(
        6,
        TaxiGenConfig {
            avg_pairs: 4,
            avg_line_len: 100,
        },
        21,
    );
    let replicated = regatta::workload::taxi::replicate(&base, 3);
    let tmp = TempFile::new("taxi_x3.txt");
    write_taxi_file(&tmp.0, &base.text, 3).unwrap();
    let app = taxi_app(TaxiVariant::Hybrid);
    let single = app.run(&replicated).unwrap();
    let source = TextSource::open(&tmp.0).unwrap();
    let text = source.text();
    let exec = ExecConfig::new(2).streaming(8);
    let streamed = app.run_streaming(text, source, &exec).unwrap();
    assert_eq!(streamed.pairs.len(), single.pairs.len());
    for (g, e) in streamed.pairs.iter().zip(&single.pairs) {
        assert_eq!((g.tag, g.x.to_bits(), g.y.to_bits()), (e.tag, e.x.to_bits(), e.y.to_bits()));
    }
}

// ---- streaming sinks -----------------------------------------------

fn jsonl_of<T: JsonRecord>(records: &[T]) -> String {
    let mut s = String::new();
    for r in records {
        r.push_json(&mut s);
        s.push('\n');
    }
    s
}

#[test]
fn file_backed_sum_through_jsonl_sink_matches_the_in_memory_run_bytes() {
    let spec = RegionSpec::Uniform { max: 30 };
    let blobs = gen_blobs(1200, spec, 8);
    let tmp = TempFile::new("sink_sum.rgn");
    write_rgn_file(&tmp.0, GenBlobSource::new(1200, spec, 8)).unwrap();
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let want = jsonl_of(&app.run(&blobs).unwrap().outputs);

    let exec = ExecConfig::new(4).streaming(16);
    let mut sink = JsonlSink::new(Vec::new());
    let report = app
        .run_streaming_into(BlobFileSource::open(&tmp.0).unwrap(), &exec, &mut sink)
        .unwrap();
    assert!(report.outputs.is_empty(), "sink consumed the outputs");
    let stats = ResultSink::<(u64, f64)>::finish(&mut sink).unwrap();
    assert_eq!(stats.records as usize, blobs.len());
    let got = String::from_utf8(sink.into_inner()).unwrap();
    assert_eq!(got, want, "byte-identical JSONL from the file-backed run");
}

#[test]
fn file_backed_taxi_through_jsonl_sink_matches_the_in_memory_run_bytes() {
    let w = generate(
        16,
        TaxiGenConfig {
            avg_pairs: 5,
            avg_line_len: 140,
        },
        31,
    );
    let tmp = TempFile::new("sink_taxi.txt");
    write_taxi_file(&tmp.0, &w.text, 1).unwrap();
    let app = taxi_app(TaxiVariant::Hybrid);
    let want = jsonl_of(&app.run(&w).unwrap().pairs);

    let source = TextSource::open(&tmp.0).unwrap();
    let text = source.text();
    let exec = ExecConfig::new(3).streaming(8);
    let mut sink = JsonlSink::new(Vec::new());
    let report = app.run_streaming_into(text, source, &exec, &mut sink).unwrap();
    assert!(report.pairs.is_empty());
    let stats = ResultSink::<TaxiPair>::finish(&mut sink).unwrap();
    assert_eq!(stats.records as usize, w.total_pairs);
    let got = String::from_utf8(sink.into_inner()).unwrap();
    assert_eq!(got, want, "byte-identical JSONL from the file-backed run");
}

#[test]
fn binary_sink_decodes_back_to_the_exact_sums() {
    let spec = RegionSpec::Fixed { size: 17 };
    let blobs = gen_blobs(600, spec, 12);
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let want = app.run(&blobs).unwrap().outputs;

    let exec = ExecConfig::new(2).streaming(16);
    let mut sink = BinarySink::new(Vec::new());
    app.run_streaming_into(GenBlobSource::new(600, spec, 12), &exec, &mut sink)
        .unwrap();
    let stats = ResultSink::<(u64, f64)>::finish(&mut sink).unwrap();
    assert_eq!(stats.records as usize, want.len());
    let bytes = sink.into_inner();
    assert_eq!(&bytes[..8], b"RGNRES.1");
    let mut got = Vec::new();
    for rec in bytes[16..].chunks_exact(16) {
        got.push((
            u64::from_le_bytes(rec[..8].try_into().unwrap()),
            f64::from_le_bytes(rec[8..].try_into().unwrap()),
        ));
    }
    assert_sums_bitwise(&got, &want, "binary sink");
}

#[test]
fn tagged_mode_refuses_streaming_sinks_by_name() {
    let app = sum_app(SumMode::Tagged, SumShape::Fused);
    let exec = ExecConfig::new(2).streaming(16);
    let mut sink = JsonlSink::new(Vec::new());
    let err = app
        .run_streaming_into(
            GenBlobSource::new(100, RegionSpec::Fixed { size: 5 }, 1),
            &exec,
            &mut sink,
        )
        .unwrap_err();
    assert!(err.to_string().contains("Tagged"), "{err}");
}

// ---- ingest-buffer validation through the app fronts ---------------

#[test]
fn zero_ingest_buffer_is_a_named_error_through_every_entry_point() {
    let exec = ExecConfig::new(2).streaming(0);
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let err = app
        .run_streaming(GenBlobSource::new(100, RegionSpec::Fixed { size: 5 }, 1), &exec)
        .unwrap_err();
    assert!(err.to_string().contains("buffer_regions = 0"), "{err}");

    let w = generate(
        4,
        TaxiGenConfig {
            avg_pairs: 3,
            avg_line_len: 60,
        },
        2,
    );
    let taxi = taxi_app(TaxiVariant::Hybrid);
    let err = taxi
        .run_streaming(
            w.text.clone(),
            regatta::workload::source::SliceSource::new(&w.lines),
            &exec,
        )
        .unwrap_err();
    assert!(err.to_string().contains("buffer_regions = 0"), "{err}");

    let mut sink = JsonlSink::new(Vec::new());
    let err = app
        .run_streaming_into(
            GenBlobSource::new(100, RegionSpec::Fixed { size: 5 }, 1),
            &exec,
            &mut sink,
        )
        .unwrap_err();
    assert!(err.to_string().contains("buffer_regions = 0"), "{err}");
}

#[test]
fn absurd_ingest_buffer_is_a_named_error() {
    let exec = ExecConfig::new(2).streaming(usize::MAX);
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let err = app
        .run_streaming(GenBlobSource::new(10, RegionSpec::Fixed { size: 5 }, 1), &exec)
        .unwrap_err();
    assert!(err.to_string().contains("sanity cap"), "{err}");
}

// ---- pooled synthetic source through the executor ------------------

#[test]
fn pooled_gen_source_streams_bit_identically() {
    use regatta::apps::sum::SumFactory;
    use regatta::exec::{ContainerPool, KernelSpawn, ShardedRunner};

    let spec = RegionSpec::Skewed { max: 200 };
    let blobs = gen_blobs(2000, spec, 14);
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let single = app.run(&blobs).unwrap();

    let pool = Arc::new(ContainerPool::new());
    let cfg = SumConfig {
        width: WIDTH,
        data_cap: 256,
        signal_cap: 64,
        ..Default::default()
    };
    let factory = SumFactory::new(cfg, KernelSpawn::Native).with_elem_pool(pool.clone());
    let runner = ShardedRunner::new(ExecConfig::new(4).streaming(32));
    let report = runner
        .run_stream(&factory, GenBlobSource::new(2000, spec, 14).with_pool(pool))
        .unwrap();
    assert_sums_bitwise(&report.outputs, &single.outputs, "pooled gen source");
}
