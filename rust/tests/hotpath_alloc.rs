//! The tentpole invariant, pinned: **the steady-state firing path
//! performs zero heap allocations per ensemble.**
//!
//! The crate's global allocator counts per-thread allocations
//! (`regatta::util::alloc_count`), so these tests are deterministic even
//! with sibling tests running concurrently in the same binary.
//!
//! Two tiers:
//! * node-level — after a warmup firing has grown every reusable buffer
//!   (ensemble scratch, kernel staging, emitter stage, pre-reserved
//!   rings), hundreds of further firings must allocate **exactly zero**
//!   bytes;
//! * pipeline-level — a full enumerated sum run's allocation count must
//!   not scale with the number of ensembles (same region count, 50x the
//!   elements → same allocations).
//!
//! Plus the fault-tolerance rider: the pool now runs every shard behind
//! `catch_unwind`, and that guard must be free on the fault-free path —
//! wrapping a warmed shard window in `catch_unwind` costs exactly the
//! same allocations as calling it bare.

use std::rc::Rc;

use anyhow::Result;
use regatta::apps::prefix_mask;
use regatta::apps::sum::{SumApp, SumConfig, SumMode, SumShape};
use regatta::coordinator::channel::Channel;
use regatta::coordinator::node::{Emitter, Node, NodeLogic, NodeOps, Output};
use regatta::coordinator::signal::ParentRef;
use regatta::coordinator::{Policy, Scheduler};
use regatta::runtime::kernels::KernelSet;
use regatta::trace::TraceSpec;
use regatta::util::alloc_count;
use regatta::workload::regions::{gen_blobs, RegionSpec};

const W: usize = 16;

/// Filter+scale stage using the in-place kernel with logic-owned buffers
/// (the shape every app stage uses after this PR).
struct FilterStage {
    ks: Rc<KernelSet>,
    vals: Vec<f32>,
    mask: Vec<i32>,
    ov: Vec<f32>,
    om: Vec<i32>,
}

impl FilterStage {
    fn new(ks: Rc<KernelSet>) -> FilterStage {
        FilterStage {
            ks,
            vals: vec![0.0; W],
            mask: Vec::with_capacity(W),
            ov: vec![0.0; W],
            om: vec![0; W],
        }
    }
}

impl NodeLogic for FilterStage {
    type In = f32;
    type Out = f32;

    fn run(
        &mut self,
        items: &[f32],
        _parent: Option<&ParentRef>,
        out: &mut Emitter<'_, f32>,
    ) -> Result<()> {
        self.vals[..items.len()].copy_from_slice(items);
        for s in self.vals[items.len()..].iter_mut() {
            *s = 0.0;
        }
        prefix_mask(&mut self.mask, items.len(), W);
        self.ks
            .filter_scale_into(&self.vals, &self.mask, 0.0, &mut self.ov, &mut self.om)?;
        for i in 0..items.len() {
            if self.om[i] != 0 {
                out.push(self.ov[i]);
            }
        }
        Ok(())
    }

    fn max_outputs_per_input(&self) -> usize {
        1
    }
}

#[test]
fn steady_state_node_firing_allocates_exactly_zero() {
    let input: Rc<Channel<f32>> = Channel::new(4 * W, 8);
    let out: Rc<Channel<f32>> = Channel::new(4 * W, 8);
    let mut node = Node::new(
        "f",
        W,
        input.clone(),
        Output::Chan(out.clone()),
        FilterStage::new(Rc::new(KernelSet::native(W))),
    );
    let mut drain: Vec<f32> = Vec::with_capacity(4 * W);

    // warmup: grow every reusable buffer to steady state
    for _ in 0..3 {
        for i in 0..W {
            input.push(i as f32 + 1.0);
        }
        assert!(node.fire().unwrap());
        out.pop_data_into(usize::MAX, &mut drain);
        assert_eq!(drain.len(), W); // all positive values survive
    }

    // steady state: feed + fire + drain, several hundred ensembles
    let before = alloc_count::thread_allocations();
    for _ in 0..300 {
        for i in 0..W {
            input.push(i as f32 + 1.0);
        }
        assert!(node.fire().unwrap());
        out.pop_data_into(usize::MAX, &mut drain);
    }
    let delta = alloc_count::thread_allocations() - before;
    assert_eq!(
        delta, 0,
        "steady-state firing path made {delta} heap allocations over 300 ensembles"
    );
}

/// One-node scheduler graph over [`FilterStage`], for driving the
/// *scheduler's* firing loop (where the trace hook lives) rather than
/// `Node::fire` directly.
fn filter_graph() -> (Vec<Box<dyn NodeOps>>, Rc<Channel<f32>>, Rc<Channel<f32>>) {
    let input: Rc<Channel<f32>> = Channel::new(4 * W, 8);
    let out: Rc<Channel<f32>> = Channel::new(4 * W, 8);
    let node = Node::new(
        "f",
        W,
        input.clone(),
        Output::Chan(out.clone()),
        FilterStage::new(Rc::new(KernelSet::native(W))),
    );
    (vec![Box::new(node)], input, out)
}

/// Feed + run-to-quiescence + drain, `rounds` times; returns the
/// allocation delta across those rounds.
fn scheduler_rounds(
    sched: &mut Scheduler,
    nodes: &mut [Box<dyn NodeOps>],
    input: &Channel<f32>,
    out: &Channel<f32>,
    drain: &mut Vec<f32>,
    rounds: usize,
) -> u64 {
    let before = alloc_count::thread_allocations();
    for _ in 0..rounds {
        for i in 0..W {
            input.push(i as f32 + 1.0);
        }
        sched.run(nodes).unwrap();
        out.pop_data_into(usize::MAX, drain);
    }
    alloc_count::thread_allocations() - before
}

#[test]
fn scheduler_steady_state_allocates_zero_with_tracing_off() {
    // the trace subsystem's first invariant: with tracing off (the
    // default) the scheduler's per-firing hook is a single branch —
    // the steady-state loop stays at exactly zero allocations
    let (mut nodes, input, out) = filter_graph();
    let mut sched = Scheduler::new(Policy::GreedyOccupancy);
    let mut drain: Vec<f32> = Vec::with_capacity(4 * W);
    scheduler_rounds(&mut sched, &mut nodes, &input, &out, &mut drain, 3); // warmup
    let delta = scheduler_rounds(&mut sched, &mut nodes, &input, &out, &mut drain, 300);
    assert_eq!(
        delta, 0,
        "untraced scheduler loop made {delta} heap allocations over 300 rounds"
    );
}

#[test]
fn scheduler_steady_state_allocates_zero_with_tracing_on() {
    // the second invariant: with tracing ON, recording is a clock read
    // plus a store into the sink's preallocated buffer — still exactly
    // zero steady-state allocations (the buffer was reserved up front)
    let (mut nodes, input, out) = filter_graph();
    let mut sched = Scheduler::new(Policy::GreedyOccupancy);
    let sink = TraceSpec::new(1 << 16).sink();
    sched.set_trace(sink.clone());
    let mut drain: Vec<f32> = Vec::with_capacity(4 * W);
    scheduler_rounds(&mut sched, &mut nodes, &input, &out, &mut drain, 3); // warmup
    let delta = scheduler_rounds(&mut sched, &mut nodes, &input, &out, &mut drain, 300);
    assert_eq!(
        delta, 0,
        "traced scheduler loop made {delta} heap allocations over 300 rounds"
    );
    let (records, dropped) = sink.take();
    assert!(records.len() >= 300, "one firing event per round at least");
    assert_eq!(dropped, 0, "capacity 64Ki must not drop a ~600-event run");
}

#[test]
fn steady_state_reduction_firing_allocates_exactly_zero() {
    /// Fused sum stage (scalar-returning kernel, accumulator only).
    struct SumStage {
        ks: Rc<KernelSet>,
        vals: Vec<f32>,
        mask: Vec<i32>,
        acc: f64,
    }
    impl NodeLogic for SumStage {
        type In = f32;
        type Out = f32;
        fn run(
            &mut self,
            items: &[f32],
            _parent: Option<&ParentRef>,
            _out: &mut Emitter<'_, f32>,
        ) -> Result<()> {
            self.vals[..items.len()].copy_from_slice(items);
            for s in self.vals[items.len()..].iter_mut() {
                *s = 0.0;
            }
            prefix_mask(&mut self.mask, items.len(), W);
            let (partial, _) = self.ks.sum_region(&self.vals, &self.mask, 0.0)?;
            self.acc += partial as f64;
            Ok(())
        }
        fn max_outputs_per_input(&self) -> usize {
            0
        }
    }

    let input: Rc<Channel<f32>> = Channel::new(4 * W, 8);
    let sink = Rc::new(std::cell::RefCell::new(Vec::new()));
    let mut node = Node::new(
        "sum",
        W,
        input.clone(),
        Output::Sink(sink),
        SumStage {
            ks: Rc::new(KernelSet::native(W)),
            vals: vec![0.0; W],
            mask: Vec::with_capacity(W),
            acc: 0.0,
        },
    );
    for _ in 0..2 {
        for i in 0..W {
            input.push(i as f32);
        }
        assert!(node.fire().unwrap());
    }
    let before = alloc_count::thread_allocations();
    for _ in 0..300 {
        for i in 0..W {
            input.push(i as f32);
        }
        assert!(node.fire().unwrap());
    }
    let delta = alloc_count::thread_allocations() - before;
    assert_eq!(delta, 0, "reduction firing path made {delta} allocations");
}

#[test]
fn cross_shard_reuse_allocations_do_not_scale_with_shard_count() {
    // The worker-side reuse contract: a persistent SumPipeline, reset
    // between shards, pays only the inherent per-shard costs (feeding
    // region clones, one Rc parent per region, the output vector, the
    // metrics snapshot) — never a graph rebuild. Three checks:
    //  1. re-running the same warmed shard window costs the same
    //     (constant per-shard slope — reset itself allocates nothing);
    //  2. the reused slope is a fraction of the rebuild-per-shard cost
    //     (the overhead this PR removes);
    use regatta::apps::sum::SumPipeline;
    let cfg = SumConfig {
        width: W,
        mode: SumMode::Enumerated,
        shape: SumShape::Fused,
        data_cap: 256,
        signal_cap: 64,
        ..Default::default()
    };
    let ks = Rc::new(KernelSet::native(W));
    let blobs = gen_blobs(240 * W, RegionSpec::Fixed { size: W }, 5); // 240 regions
    let shards: Vec<&[regatta::prelude::Blob]> = blobs.chunks(2).collect();

    let mut pipeline = SumPipeline::build(cfg, ks.clone());
    for shard in shards.iter().take(20) {
        pipeline.run_shard(shard).unwrap(); // warmup: grow every buffer
    }
    let run_window = |pipeline: &mut SumPipeline| -> u64 {
        let before = alloc_count::thread_allocations();
        for shard in &shards[20..70] {
            pipeline.run_shard(shard).unwrap();
        }
        alloc_count::thread_allocations() - before
    };
    let first = run_window(&mut pipeline);
    let second = run_window(&mut pipeline);
    assert!(
        second <= first + 8,
        "reused pipeline accumulates allocations across shards: {first} then {second} \
         over the same 50-shard window"
    );

    let app = SumApp::new(cfg, ks);
    let before = alloc_count::thread_allocations();
    for shard in &shards[20..70] {
        app.run(shard).unwrap(); // fresh build per shard: the old behaviour
    }
    let rebuilt = alloc_count::thread_allocations() - before;
    assert!(
        2 * second <= rebuilt,
        "reuse should cost well under half of rebuild per shard: reused {second} vs \
         rebuilt {rebuilt} allocations over 50 shards"
    );
}

#[test]
fn cross_shard_reuse_allocations_do_not_scale_with_ensembles() {
    // same regions per shard, 50x the elements (≈50x the ensembles):
    // a warmed reused pipeline shows the same allocation count, because
    // every per-shard allocation is region-granular (clone-feed, Rc
    // parent, output vector) — reset adds nothing ensemble-shaped
    use regatta::apps::sum::SumPipeline;
    let cfg = SumConfig {
        width: 8,
        mode: SumMode::Enumerated,
        shape: SumShape::Fused,
        data_cap: 256,
        signal_cap: 64,
        ..Default::default()
    };
    let small = gen_blobs(40 * 8, RegionSpec::Fixed { size: 8 }, 42); // 40 regions
    let large = gen_blobs(40 * 400, RegionSpec::Fixed { size: 400 }, 42); // 40 regions
    let mut pipeline = SumPipeline::build(cfg, Rc::new(KernelSet::native(8)));
    for shard in large.chunks(4) {
        pipeline.run_shard(shard).unwrap(); // warm on the big shape
    }
    for shard in small.chunks(4) {
        pipeline.run_shard(shard).unwrap();
    }

    let before = alloc_count::thread_allocations();
    for shard in small.chunks(4) {
        pipeline.run_shard(shard).unwrap();
    }
    let allocs_small = alloc_count::thread_allocations() - before;

    let before = alloc_count::thread_allocations();
    for shard in large.chunks(4) {
        pipeline.run_shard(shard).unwrap();
    }
    let allocs_large = alloc_count::thread_allocations() - before;

    assert!(
        allocs_large <= allocs_small + 16,
        "cross-shard allocations scale with ensembles: {allocs_small} (small shards) vs \
         {allocs_large} (50x elements)"
    );
}

#[test]
fn pipeline_allocations_do_not_scale_with_ensemble_count() {
    // same number of regions (so identical counts of region-granular
    // allocations: Rc parents, sink growth, feed clones), but 50x the
    // elements — i.e. ~50x the ensembles. A per-ensemble allocation
    // anywhere on the firing path would separate the two counts by
    // thousands.
    let app = |width: usize| {
        SumApp::new(
            SumConfig {
                width,
                mode: SumMode::Enumerated,
                shape: SumShape::Fused,
                data_cap: 256,
                signal_cap: 64,
                ..Default::default()
            },
            Rc::new(KernelSet::native(width)),
        )
    };
    const REGIONS: usize = 100;
    let small = gen_blobs(REGIONS * 8, RegionSpec::Fixed { size: 8 }, 42);
    let large = gen_blobs(REGIONS * 400, RegionSpec::Fixed { size: 400 }, 42);
    assert_eq!(small.len(), REGIONS);
    assert_eq!(large.len(), REGIONS);

    let a = app(8);
    // warm the process (lazy statics, first-run effects)
    a.run(&small).unwrap();

    let before = alloc_count::thread_allocations();
    let rs = a.run(&small).unwrap();
    let allocs_small = alloc_count::thread_allocations() - before;

    let before = alloc_count::thread_allocations();
    let rl = a.run(&large).unwrap();
    let allocs_large = alloc_count::thread_allocations() - before;

    let ens_small = rs.metrics.node("sum").unwrap().ensembles;
    let ens_large = rl.metrics.node("sum").unwrap().ensembles;
    assert!(
        ens_large >= 40 * ens_small,
        "expected ~50x ensembles, got {ens_small} vs {ens_large}"
    );
    // identical region-granular work => near-identical allocation counts;
    // a tiny slack absorbs amortized growth of long-lived buffers
    assert!(
        allocs_large <= allocs_small + 16,
        "allocations scale with ensembles: {allocs_small} (x{ens_small} ensembles) vs \
         {allocs_large} (x{ens_large} ensembles)"
    );
}

#[test]
fn catch_unwind_guard_adds_no_steady_state_allocations() {
    // The fault-tolerance layer wraps every shard execution in
    // `catch_unwind` (see `regatta::exec::fault`). On the fault-free
    // path that guard must be pure control flow: running the same warmed
    // 50-shard window bare and wrapped must cost identical allocations
    // (a successful catch_unwind never touches the heap — only a caught
    // panic payload would).
    use regatta::apps::sum::SumPipeline;
    let cfg = SumConfig {
        width: W,
        mode: SumMode::Enumerated,
        shape: SumShape::Fused,
        data_cap: 256,
        signal_cap: 64,
        ..Default::default()
    };
    let blobs = gen_blobs(140 * W, RegionSpec::Fixed { size: W }, 9); // 140 regions
    let shards: Vec<&[regatta::prelude::Blob]> = blobs.chunks(2).collect();
    let mut pipeline = SumPipeline::build(cfg, Rc::new(KernelSet::native(W)));
    for shard in shards.iter().take(20) {
        pipeline.run_shard(shard).unwrap(); // warmup: grow every buffer
    }

    let before = alloc_count::thread_allocations();
    for shard in &shards[20..70] {
        pipeline.run_shard(shard).unwrap();
    }
    let bare = alloc_count::thread_allocations() - before;

    let before = alloc_count::thread_allocations();
    for shard in &shards[20..70] {
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pipeline.run_shard(shard)
        }));
        out.expect("no panic injected").unwrap();
    }
    let guarded = alloc_count::thread_allocations() - before;

    assert!(
        guarded <= bare + 8,
        "catch_unwind must be allocation-free on the fault-free path: \
         {bare} bare vs {guarded} guarded over the same 50-shard window"
    );
}
