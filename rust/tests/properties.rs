//! Property-based tests (minicheck): the paper's lemmas and the
//! coordinator's invariants over randomized workloads, topologies and
//! queue capacities.

use std::cell::RefCell;
use std::rc::Rc;

use regatta::coordinator::aggregate::{Aggregator, FilterMapLogic, MapLogic};
use regatta::coordinator::channel::Channel;
use regatta::coordinator::enumerate::Blob;
use regatta::coordinator::node::{Emitter, Node, NodeLogic, NodeOps, Output};
use regatta::coordinator::signal::{ParentRef, SignalKind};
use regatta::coordinator::topology::PipelineBuilder;
use regatta::coordinator::scheduler::Policy;
use regatta::util::minicheck::Checker;
use regatta::workload::regions::{gen_blobs, RegionSpec};

/// Lemma 1 (precise delivery) under fully random emission/consumption
/// interleavings, widths and queue capacities.
#[test]
fn prop_lemma1_precise_delivery() {
    struct Recorder {
        consumed: Rc<RefCell<u64>>,
        deliveries: Rc<RefCell<Vec<(u64, u64)>>>,
    }
    impl NodeLogic for Recorder {
        type In = u64;
        type Out = u64;
        fn run(
            &mut self,
            items: &[u64],
            _p: Option<&ParentRef>,
            _o: &mut Emitter<'_, u64>,
        ) -> anyhow::Result<()> {
            *self.consumed.borrow_mut() += items.len() as u64;
            Ok(())
        }
        fn on_custom(&mut self, id: u64, _o: &mut Emitter<'_, u64>) -> anyhow::Result<()> {
            self.deliveries.borrow_mut().push((id, *self.consumed.borrow()));
            Ok(())
        }
        fn max_outputs_per_input(&self) -> usize {
            0
        }
        fn forward_region_signals(&self) -> bool {
            false
        }
    }

    Checker::new("lemma1-precise-delivery").runs(150).check(|g| {
        let width = g.int_in(1, 16);
        let data_cap = g.int_in(8, 2048);
        let sig_cap = g.int_in(4, 256);
        let ch: Rc<Channel<u64>> = Channel::new(data_cap, sig_cap);
        let consumed = Rc::new(RefCell::new(0u64));
        let deliveries = Rc::new(RefCell::new(Vec::new()));
        let sink = Rc::new(RefCell::new(Vec::new()));
        let mut node = Node::new(
            "rec",
            width,
            ch.clone(),
            Output::Sink(sink),
            Recorder {
                consumed: consumed.clone(),
                deliveries: deliveries.clone(),
            },
        );

        let mut emitted = 0u64;
        let mut sig_id = 0u64;
        let mut expected = Vec::new();
        let steps = g.int_in(10, 120);
        for _ in 0..steps {
            match g.int_in(0, 2) {
                0 => {
                    let burst = g.int_in(0, 8);
                    for _ in 0..burst {
                        if ch.data_space() > 0 {
                            ch.push(emitted);
                            emitted += 1;
                        }
                    }
                }
                1 => {
                    if ch.signal_space() > 0 {
                        ch.emit_signal(SignalKind::Custom(sig_id));
                        expected.push((sig_id, emitted));
                        sig_id += 1;
                    }
                }
                _ => {
                    let fires = g.int_in(0, 5);
                    for _ in 0..fires {
                        if node.fireable() {
                            node.fire().map_err(|e| e.to_string())?;
                        }
                    }
                }
            }
        }
        while node.fireable() {
            node.fire().map_err(|e| e.to_string())?;
        }
        if *consumed.borrow() != emitted {
            return Err(format!(
                "consumed {} != emitted {emitted}",
                *consumed.borrow()
            ));
        }
        let got = deliveries.borrow();
        if *got != expected {
            return Err(format!("deliveries {:?} != expected {:?}", *got, expected));
        }
        Ok(())
    });
}

/// Lemma 2 (no deadlock): random linear pipelines with random queue
/// capacities, region structures and logic fan-outs always quiesce, and
/// conservation holds (every emitted item is consumed somewhere).
#[test]
fn prop_lemma2_no_deadlock_random_pipelines() {
    Checker::new("lemma2-no-deadlock").runs(120).check(|g| {
        let width = g.int_in(1, 12);
        let data_cap = g.int_in(4, 256).max(width); // ≥ one ensemble
        let sig_cap = g.int_in(2, 64);
        let n_blobs = g.int_in(0, 25);
        let max_region = g.int_in(0, 40);
        let fanout = g.int_in(1, 3); // middle node outputs per input

        let mut b = PipelineBuilder::new(width).queue_caps(data_cap, sig_cap);
        let src = b.source_with_cap::<Blob>(n_blobs.max(1));
        let elems = b.enumerate("enum", &src);
        let mid = b.node(
            "mid",
            &elems,
            FilterMapLogic::new(fanout, move |idxs: &[u32], _p, out: &mut Emitter<'_, u32>| {
                for &i in idxs {
                    for _ in 0..(i as usize % (fanout + 1)) {
                        out.push(i);
                    }
                }
                Ok(())
            }),
        );
        let counts = b.sink(
            "agg",
            &mid,
            Aggregator::new(
                0u64,
                |acc: &mut u64, items: &[u32], _| {
                    *acc += items.len() as u64;
                    Ok(())
                },
                |acc: &mut u64, _| Ok(Some(*acc)),
            ),
        );

        let mut rng_seed = 0u64;
        let mut total_elems = 0usize;
        for id in 0..n_blobs {
            let size = if max_region == 0 {
                0
            } else {
                g.int_in(0, max_region)
            };
            total_elems += size;
            src.push(Blob::from_vec(id as u64, vec![1.0; size]));
            rng_seed += size as u64;
        }
        let _ = rng_seed;

        let mut pipe = b.build();
        pipe.run().map_err(|e| format!("deadlock: {e}"))?;

        // conservation: mid saw every element; agg produced one output
        // per region
        let m = pipe.metrics();
        if m.node("mid").unwrap().items as usize != total_elems {
            return Err(format!(
                "mid consumed {} of {total_elems}",
                m.node("mid").unwrap().items
            ));
        }
        if counts.borrow().len() != n_blobs {
            return Err(format!(
                "agg emitted {} sums for {n_blobs} regions",
                counts.borrow().len()
            ));
        }
        Ok(())
    });
}

/// All three scheduling policies produce identical sink contents — firing
/// order must never change semantics, only occupancy.
#[test]
fn prop_policies_agree() {
    Checker::new("policies-agree").runs(60).check(|g| {
        let width = g.int_in(1, 8);
        let n_blobs = g.int_in(1, 12);
        let max_region = g.int_in(1, 30);
        let seed = g.int_in(0, 10_000) as u64;
        let blobs = gen_blobs(
            n_blobs * max_region.max(1) / 2 + 1,
            RegionSpec::Uniform { max: max_region },
            seed,
        );

        let run = |policy: Policy| -> Result<Vec<(u64, u64)>, String> {
            let mut b = PipelineBuilder::new(width).queue_caps(64.max(width), 32).policy(policy);
            let src = b.source_with_cap::<Blob>(blobs.len());
            let elems = b.enumerate("enum", &src);
            let out = b.sink(
                "agg",
                &elems,
                Aggregator::new(
                    0u64,
                    |acc: &mut u64, items: &[u32], _| {
                        *acc += items.iter().map(|&i| i as u64 + 1).sum::<u64>();
                        Ok(())
                    },
                    |acc: &mut u64, p: &ParentRef| {
                        let blob = regatta::coordinator::signal::parent_as::<Blob>(p).unwrap();
                        Ok(Some((blob.id, *acc)))
                    },
                ),
            );
            for blob in &blobs {
                src.push(blob.clone());
            }
            let mut pipe = b.build();
            pipe.run().map_err(|e| e.to_string())?;
            let v = out.borrow().clone();
            Ok(v)
        };

        let a = run(Policy::GreedyOccupancy)?;
        let b_ = run(Policy::DeepestFirst)?;
        let c = run(Policy::RoundRobin)?;
        if a != b_ || a != c {
            return Err(format!("policy divergence: {a:?} vs {b_:?} vs {c:?}"));
        }
        Ok(())
    });
}

/// Enumeration bookkeeping: begin/end called exactly once per region, in
/// stream order, with matching parents, under random region structures.
#[test]
fn prop_begin_end_bracketing() {
    #[derive(Default)]
    struct Trace {
        events: Vec<(char, u64)>, // ('b'|'e', blob id)
    }
    struct Hooked {
        trace: Rc<RefCell<Trace>>,
    }
    impl NodeLogic for Hooked {
        type In = u32;
        type Out = u32;
        fn run(
            &mut self,
            _items: &[u32],
            parent: Option<&ParentRef>,
            _out: &mut Emitter<'_, u32>,
        ) -> anyhow::Result<()> {
            // items only ever arrive inside a region
            anyhow::ensure!(parent.is_some(), "item outside region");
            Ok(())
        }
        fn begin(&mut self, p: &ParentRef, _o: &mut Emitter<'_, u32>) -> anyhow::Result<()> {
            let blob = regatta::coordinator::signal::parent_as::<Blob>(p).unwrap();
            self.trace.borrow_mut().events.push(('b', blob.id));
            Ok(())
        }
        fn end(&mut self, p: &ParentRef, _o: &mut Emitter<'_, u32>) -> anyhow::Result<()> {
            let blob = regatta::coordinator::signal::parent_as::<Blob>(p).unwrap();
            self.trace.borrow_mut().events.push(('e', blob.id));
            Ok(())
        }
        fn max_outputs_per_input(&self) -> usize {
            0
        }
    }

    Checker::new("begin-end-bracketing").runs(80).check(|g| {
        let width = g.int_in(1, 8);
        let n = g.int_in(0, 15);
        let mut b = PipelineBuilder::new(width).queue_caps(g.int_in(8, 128), g.int_in(4, 64));
        let src = b.source_with_cap::<Blob>(n.max(1));
        let elems = b.enumerate("enum", &src);
        let trace = Rc::new(RefCell::new(Trace::default()));
        let _out = b.node(
            "hooked",
            &elems,
            Hooked {
                trace: trace.clone(),
            },
        );
        // terminal sink to absorb forwarded signals + (no) data
        let hooked_out = _out;
        let mut b2 = b; // keep builder mutable naming tidy
        let _sink = b2.sink("sink", &hooked_out, MapLogic::new(|&x: &u32| x));
        for id in 0..n {
            let size = g.int_in(0, 20);
            src.push(Blob::from_vec(id as u64, vec![0.5; size]));
        }
        let mut pipe = b2.build();
        pipe.run().map_err(|e| e.to_string())?;

        let tr = trace.borrow();
        if tr.events.len() != 2 * n {
            return Err(format!("expected {} events, got {:?}", 2 * n, tr.events));
        }
        for (i, chunk) in tr.events.chunks(2).enumerate() {
            let want = i as u64;
            if chunk != [('b', want), ('e', want)] {
                return Err(format!("region {want} mis-bracketed: {:?}", tr.events));
            }
        }
        Ok(())
    });
}

/// The sum app agrees with the f64 reference for every mode/shape at
/// random widths and region specs (routing/batching invariance).
#[test]
fn prop_sum_app_correct_everywhere() {
    use regatta::apps::sum::{reference_sums, SumApp, SumConfig, SumMode, SumShape};
    use regatta::runtime::kernels::KernelSet;

    Checker::new("sum-app-correct").runs(40).check(|g| {
        let width = *g.choose(&[2usize, 4, 8, 16]);
        let items = g.int_in(50, 2000);
        let spec = if g.chance(0.5) {
            RegionSpec::Fixed {
                size: g.int_in(1, 200),
            }
        } else {
            RegionSpec::Uniform {
                max: g.int_in(1, 200),
            }
        };
        let seed = g.int_in(0, 1 << 20) as u64;
        let blobs = gen_blobs(items, spec, seed);
        let want = reference_sums(&blobs, 0.0);

        let combos = [
            (SumMode::Enumerated, SumShape::Fused),
            (SumMode::Enumerated, SumShape::TwoStage),
            (SumMode::Tagged, SumShape::Fused),
        ];
        for (mode, shape) in combos {
            if mode == SumMode::Tagged && blobs.iter().any(|b| b.elems.is_empty()) {
                continue; // dense representation cannot express empty regions
            }
            let app = SumApp::new(
                SumConfig {
                    width,
                    mode,
                    shape,
                    data_cap: g.int_in(width.max(4), 512),
                    signal_cap: g.int_in(8, 128),
                    ..Default::default()
                },
                Rc::new(KernelSet::native(width)),
            );
            let got = app.run(&blobs).map_err(|e| e.to_string())?.outputs;
            if got.len() != want.len() {
                return Err(format!("{mode:?}/{shape:?}: {} vs {} sums", got.len(), want.len()));
            }
            for ((gi, gv), (wi, wv)) in got.iter().zip(&want) {
                if gi != wi || (gv - wv).abs() > 1e-3 * (1.0 + wv.abs()) {
                    return Err(format!("{mode:?}/{shape:?} region {wi}: {gv} vs {wv}"));
                }
            }
        }
        Ok(())
    });
}

/// Queue-capacity torture: very tight queues still quiesce and stay
/// correct (stresses the fireable space reservations).
#[test]
fn prop_tight_queues_still_correct() {
    use regatta::apps::sum::{reference_sums, SumApp, SumConfig, SumMode, SumShape};
    use regatta::runtime::kernels::KernelSet;

    Checker::new("tight-queues").runs(40).check(|g| {
        let width = g.int_in(1, 6);
        let blobs = gen_blobs(
            g.int_in(10, 300),
            RegionSpec::Uniform {
                max: g.int_in(1, 40),
            },
            g.int_in(0, 999) as u64,
        );
        let app = SumApp::new(
            SumConfig {
                width,
                mode: SumMode::Enumerated,
                shape: SumShape::Fused,
                data_cap: width.max(g.int_in(1, 4)), // brutally tight
                signal_cap: g.int_in(2, 4),
                ..Default::default()
            },
            Rc::new(KernelSet::native(width)),
        );
        let got = app.run(&blobs).map_err(|e| format!("run: {e}"))?.outputs;
        let want = reference_sums(&blobs, 0.0);
        if got.len() != want.len() {
            return Err(format!("{} vs {} sums", got.len(), want.len()));
        }
        for ((_, gv), (_, wv)) in got.iter().zip(&want) {
            if (gv - wv).abs() > 1e-3 * (1.0 + wv.abs()) {
                return Err(format!("{gv} vs {wv}"));
            }
        }
        Ok(())
    });
}
