//! Tracing is an observer, not a participant.
//!
//! The trace subsystem's contract (`regatta::trace` module docs): turning
//! tracing on changes *nothing observable* about a run — outputs are
//! bit-for-bit identical for every worker count, app and ingest mode —
//! and with zero dropped events the folded trace reconciles *exactly*
//! with the end-of-run `NodeMetrics` aggregates (one `Firing` event per
//! scheduler firing, deltas read from the node's own counters). This
//! suite pins both halves down, end to end through the Chrome JSON
//! artifact and the `trace summarize` renderer.

use std::rc::Rc;

use regatta::apps::sum::{SumApp, SumConfig, SumFactory, SumMode, SumShape};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiVariant};
use regatta::exec::{ExecConfig, KernelSpawn, ShardedRunner};
use regatta::prelude::Policy;
use regatta::runtime::kernels::{Backend, KernelSet};
use regatta::trace::{TraceEvent, TraceOptions, DRIVER_LANE};
use regatta::util::json::Json;
use regatta::workload::regions::{gen_blobs, RegionSpec};
use regatta::workload::source::SliceSource;
use regatta::workload::taxi::{generate, TaxiGenConfig};

const WIDTH: usize = 8;

fn sum_app(mode: SumMode) -> SumApp {
    SumApp::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape: SumShape::Fused,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        Rc::new(KernelSet::native(WIDTH)),
    )
}

fn sum_factory(mode: SumMode) -> SumFactory {
    SumFactory::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape: SumShape::Fused,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        KernelSpawn::from_backend(Backend::Native),
    )
}

fn traced(workers: usize) -> ExecConfig {
    // far above any event count these streams produce (dropped == 0 is
    // asserted), but small enough that parallel test threads don't each
    // pin the 2^20-record default buffer
    ExecConfig::new(workers).with_trace(Some(TraceOptions { capacity: 1 << 16 }))
}

fn assert_outputs_bitwise(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for (i, ((gi, gv), (wi, wv))) in got.iter().zip(want).enumerate() {
        assert_eq!(gi, wi, "{ctx}: region id at {i}");
        assert_eq!(
            gv.to_bits(),
            wv.to_bits(),
            "{ctx}: region {gi} sum {gv} vs {wv}"
        );
    }
}

#[test]
fn traced_sum_is_bitwise_identical_workers_1_to_8() {
    for mode in [SumMode::Enumerated, SumMode::Tagged] {
        let app = sum_app(mode);
        let blobs = gen_blobs(1500, RegionSpec::Uniform { max: 40 }, 42);
        for workers in 1..=8 {
            let plain = app
                .run_sharded_with(&blobs, &ExecConfig::new(workers))
                .unwrap();
            let traced = app.run_sharded_with(&blobs, &traced(workers)).unwrap();
            assert_outputs_bitwise(
                &traced.outputs,
                &plain.outputs,
                &format!("{mode:?} workers {workers}"),
            );
            assert_eq!(
                traced.invocations, plain.invocations,
                "{mode:?} workers {workers}: kernel invocations"
            );
        }
    }
}

#[test]
fn traced_streaming_sum_is_bitwise_identical() {
    let app = sum_app(SumMode::Enumerated);
    let blobs = gen_blobs(1200, RegionSpec::Uniform { max: 30 }, 7);
    for workers in [1usize, 2, 4, 8] {
        let plain = app
            .run_streaming(SliceSource::new(&blobs), &ExecConfig::new(workers))
            .unwrap();
        let traced = app
            .run_streaming(SliceSource::new(&blobs), &traced(workers))
            .unwrap();
        assert_outputs_bitwise(
            &traced.outputs,
            &plain.outputs,
            &format!("streamed workers {workers}"),
        );
    }
}

#[test]
fn traced_taxi_is_bitwise_identical() {
    let w = generate(
        20,
        TaxiGenConfig {
            avg_pairs: 6,
            avg_line_len: 160,
        },
        99,
    );
    for variant in TaxiVariant::all() {
        let app = TaxiApp::new(
            TaxiConfig {
                width: WIDTH,
                variant,
                data_cap: 512,
                signal_cap: 128,
                policy: Policy::GreedyOccupancy,
            },
            Rc::new(KernelSet::native(WIDTH)),
        );
        for workers in [1usize, 3] {
            let plain = app.run_sharded_with(&w, &ExecConfig::new(workers)).unwrap();
            let traced = app.run_sharded_with(&w, &traced(workers)).unwrap();
            assert_eq!(
                traced.pairs.len(),
                plain.pairs.len(),
                "{variant:?} workers {workers}: pair count"
            );
            for (i, (g, e)) in traced.pairs.iter().zip(&plain.pairs).enumerate() {
                assert_eq!(g.tag, e.tag, "{variant:?} workers {workers}: tag at {i}");
                assert_eq!(
                    g.x.to_bits(),
                    e.x.to_bits(),
                    "{variant:?} workers {workers}: x at {i}"
                );
                assert_eq!(
                    g.y.to_bits(),
                    e.y.to_bits(),
                    "{variant:?} workers {workers}: y at {i}"
                );
            }
        }
    }
}

/// With zero drops, trace totals equal the `NodeMetrics` sums *exactly*
/// — not approximately: both read the same per-firing counters.
#[test]
fn materialized_trace_reconciles_with_node_metrics() {
    let factory = sum_factory(SumMode::Enumerated);
    let blobs = gen_blobs(2000, RegionSpec::Uniform { max: 25 }, 5);
    for workers in [1usize, 3, 8] {
        let report = ShardedRunner::new(traced(workers))
            .run(&factory, &blobs)
            .unwrap();
        let trace = report.trace.as_ref().expect("trace attached");
        let ctx = format!("workers {workers}");
        assert_eq!(trace.dropped(), 0, "{ctx}: drops");
        let want_firings: u64 = report.metrics.nodes.iter().map(|(_, m)| m.firings).sum();
        let want_ensembles: u64 = report.metrics.nodes.iter().map(|(_, m)| m.ensembles).sum();
        let want_items: u64 = report.metrics.nodes.iter().map(|(_, m)| m.items).sum();
        assert_eq!(trace.firings(), want_firings, "{ctx}: firings");
        assert_eq!(trace.ensembles(), want_ensembles, "{ctx}: ensembles");
        assert_eq!(trace.items(), want_items, "{ctx}: items");
        assert_eq!(trace.shards(), report.shards as u64, "{ctx}: shard spans");
        assert_eq!(
            trace.stolen_shards(),
            report.steals as u64,
            "{ctx}: stolen spans"
        );
        // node table mirrors the metrics table, in order
        assert_eq!(trace.nodes.len(), report.metrics.nodes.len(), "{ctx}");
        for ((tn, tw), (mn, m)) in trace.nodes.iter().zip(&report.metrics.nodes) {
            assert_eq!(tn, mn, "{ctx}: node name");
            assert_eq!(*tw, m.width, "{ctx}: node width");
        }
        // every lane that ran a shard prewarmed exactly once, before its
        // first shard span
        for lane in &trace.workers {
            let prewarms = lane
                .records
                .iter()
                .filter(|r| r.event == TraceEvent::Prewarm)
                .count();
            assert_eq!(prewarms, 1, "{ctx}: worker {} prewarms", lane.worker);
            assert_eq!(
                lane.records[0].event,
                TraceEvent::Prewarm,
                "{ctx}: worker {} prewarm ordering",
                lane.worker
            );
        }
    }
}

/// Streaming runs add the driver lane: every planner cut is matched by
/// an in-order emission, and both match the executed shard spans.
#[test]
fn streaming_trace_reconciles_driver_and_workers() {
    let factory = sum_factory(SumMode::Enumerated);
    let blobs = gen_blobs(1600, RegionSpec::Uniform { max: 20 }, 17);
    for workers in [1usize, 4] {
        let report = ShardedRunner::new(traced(workers))
            .run_stream(&factory, SliceSource::new(&blobs))
            .unwrap();
        let trace = report.trace.as_ref().expect("trace attached");
        let ctx = format!("streamed workers {workers}");
        assert_eq!(trace.dropped(), 0, "{ctx}: drops");
        assert_eq!(trace.shards(), report.shards as u64, "{ctx}: shard spans");
        assert_eq!(trace.submits(), trace.shards(), "{ctx}: submits");
        assert_eq!(trace.emits(), trace.shards(), "{ctx}: emits");
        let want_firings: u64 = report.metrics.nodes.iter().map(|(_, m)| m.firings).sum();
        assert_eq!(trace.firings(), want_firings, "{ctx}: firings");
        let driver = trace
            .workers
            .iter()
            .find(|w| w.worker == DRIVER_LANE)
            .expect("driver lane present");
        assert!(
            driver
                .records
                .iter()
                .all(|r| matches!(r.event, TraceEvent::Submit { .. }
                    | TraceEvent::Stall { .. }
                    | TraceEvent::Emit { .. })),
            "{ctx}: driver lane records only ingest/merge events"
        );
        // driver lane sorts last; worker lanes are sorted by id
        assert_eq!(trace.workers.last().unwrap().worker, DRIVER_LANE, "{ctx}");
    }
}

/// The `--trace` artifact round-trips through the vendored JSON reader
/// and its `"regatta"` totals object matches the live trace and the
/// run's own metrics. `trace summarize` renders it without error.
#[test]
fn chrome_artifact_parses_and_reconciles() {
    let factory = sum_factory(SumMode::Enumerated);
    let blobs = gen_blobs(1000, RegionSpec::Uniform { max: 30 }, 23);
    let report = ShardedRunner::new(traced(3))
        .run_stream(&factory, SliceSource::new(&blobs))
        .unwrap();
    let trace = report.trace.as_ref().unwrap();
    let text = regatta::trace::chrome::to_chrome_json(trace);
    let json = Json::parse(&text).expect("artifact parses with util::json");

    let events = json.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    for e in events {
        assert!(e.get("ph").and_then(Json::as_str).is_some(), "phase field");
        assert!(e.get("tid").and_then(Json::as_usize).is_some(), "tid field");
    }
    let spans = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .count();
    assert_eq!(spans, trace.events(), "one X event per trace record");

    let meta = json.get("regatta").expect("totals footer");
    let total = |key: &str| meta.get(key).and_then(Json::as_usize).unwrap() as u64;
    assert_eq!(total("firings"), trace.firings());
    assert_eq!(total("ensembles"), trace.ensembles());
    assert_eq!(total("items"), trace.items());
    assert_eq!(total("shards"), report.shards as u64);
    assert_eq!(total("submits"), total("emits"));
    assert_eq!(total("dropped"), 0);
    let want_items: u64 = report.metrics.nodes.iter().map(|(_, m)| m.items).sum();
    assert_eq!(total("items"), want_items, "artifact ≡ NodeMetrics");
    let nodes = meta.get("nodes").unwrap().as_arr().unwrap();
    assert_eq!(nodes.len(), report.metrics.nodes.len());

    let rendered = regatta::trace::summary::summarize(&text, 12).unwrap();
    assert!(rendered.contains("occupancy"), "summary renders timeline");
    assert!(rendered.contains("worker"), "summary renders lanes");
}

/// An untraced config attaches nothing: the report stays trace-free and
/// the hot path never sees an enabled sink.
#[test]
fn untraced_run_attaches_no_trace() {
    let factory = sum_factory(SumMode::Enumerated);
    let blobs = gen_blobs(400, RegionSpec::Fixed { size: 9 }, 3);
    let report = ShardedRunner::new(ExecConfig::new(3))
        .run(&factory, &blobs)
        .unwrap();
    assert!(report.trace.is_none());
    let report = ShardedRunner::new(ExecConfig::new(2))
        .run_stream(&factory, SliceSource::new(&blobs))
        .unwrap();
    assert!(report.trace.is_none());
}
