//! Metering is an observer, not a participant.
//!
//! The metrics subsystem's contract (`regatta::metrics` module docs):
//! turning metrics on changes *nothing observable* about a run — outputs
//! are bit-for-bit identical for every worker count, app, ingest mode,
//! split setting and fault policy — and the folded [`MetricsReport`]
//! reconciles *exactly* with the [`ExecReport`] it rides on: same shard,
//! region, steal, retry and fault totals, one e2e histogram sample per
//! emitted region. This suite pins both halves down, end to end through
//! the `--metrics` JSON artifact, the `trace summarize` latency section
//! (re-derived offline from Submit/Emit spans) and the `--progress-secs`
//! heartbeat of the real CLI binary.
//!
//! [`MetricsReport`]: regatta::metrics::MetricsReport
//! [`ExecReport`]: regatta::exec::ExecReport

use std::rc::Rc;

use regatta::apps::sum::{finish_sharded_outputs, SumApp, SumConfig, SumFactory, SumMode, SumShape};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiVariant};
use regatta::exec::{
    ExecConfig, FaultKind, FaultPlan, FaultPolicy, FaultShot, FaultyFactory, KernelSpawn,
    ShardedRunner,
};
use regatta::metrics::{LaneMetrics, MetricsReport};
use regatta::prelude::Policy;
use regatta::runtime::kernels::{Backend, KernelSet};
use regatta::trace::TraceOptions;
use regatta::workload::regions::{gen_blobs, RegionSpec};
use regatta::workload::source::SliceSource;
use regatta::workload::taxi::{generate, TaxiGenConfig};

const WIDTH: usize = 8;

fn sum_app(mode: SumMode) -> SumApp {
    SumApp::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape: SumShape::Fused,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        Rc::new(KernelSet::native(WIDTH)),
    )
}

fn sum_factory(mode: SumMode) -> SumFactory {
    SumFactory::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape: SumShape::Fused,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        KernelSpawn::from_backend(Backend::Native),
    )
}

fn metered(workers: usize) -> ExecConfig {
    ExecConfig::new(workers).with_metrics(true)
}

fn assert_outputs_bitwise(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for (i, ((gi, gv), (wi, wv))) in got.iter().zip(want).enumerate() {
        assert_eq!(gi, wi, "{ctx}: region id at {i}");
        assert_eq!(
            gv.to_bits(),
            wv.to_bits(),
            "{ctx}: region {gi} sum {gv} vs {wv}"
        );
    }
}

#[test]
fn metered_sum_is_bitwise_identical_workers_1_to_8() {
    for mode in [SumMode::Enumerated, SumMode::Tagged] {
        let app = sum_app(mode);
        let blobs = gen_blobs(1500, RegionSpec::Uniform { max: 40 }, 42);
        for workers in 1..=8 {
            let plain = app
                .run_sharded_with(&blobs, &ExecConfig::new(workers))
                .unwrap();
            let m = app.run_sharded_with(&blobs, &metered(workers)).unwrap();
            assert_outputs_bitwise(
                &m.outputs,
                &plain.outputs,
                &format!("{mode:?} workers {workers}"),
            );
            assert_eq!(
                m.invocations, plain.invocations,
                "{mode:?} workers {workers}: kernel invocations"
            );
        }
    }
}

#[test]
fn metered_streaming_sum_is_bitwise_identical() {
    let app = sum_app(SumMode::Enumerated);
    let blobs = gen_blobs(1200, RegionSpec::Uniform { max: 30 }, 7);
    for workers in [1usize, 2, 4, 8] {
        let plain = app
            .run_streaming(SliceSource::new(&blobs), &ExecConfig::new(workers))
            .unwrap();
        let m = app
            .run_streaming(SliceSource::new(&blobs), &metered(workers))
            .unwrap();
        assert_outputs_bitwise(
            &m.outputs,
            &plain.outputs,
            &format!("streamed workers {workers}"),
        );
    }
}

#[test]
fn metered_taxi_is_bitwise_identical() {
    let w = generate(
        20,
        TaxiGenConfig {
            avg_pairs: 6,
            avg_line_len: 160,
        },
        99,
    );
    for variant in TaxiVariant::all() {
        let app = TaxiApp::new(
            TaxiConfig {
                width: WIDTH,
                variant,
                data_cap: 512,
                signal_cap: 128,
                policy: Policy::GreedyOccupancy,
            },
            Rc::new(KernelSet::native(WIDTH)),
        );
        for workers in [1usize, 3] {
            let plain = app.run_sharded_with(&w, &ExecConfig::new(workers)).unwrap();
            let m = app.run_sharded_with(&w, &metered(workers)).unwrap();
            assert_eq!(
                m.pairs.len(),
                plain.pairs.len(),
                "{variant:?} workers {workers}: pair count"
            );
            for (i, (g, e)) in m.pairs.iter().zip(&plain.pairs).enumerate() {
                assert_eq!(g.tag, e.tag, "{variant:?} workers {workers}: tag at {i}");
                assert_eq!(g.x.to_bits(), e.x.to_bits(), "{variant:?} w{workers}: x {i}");
                assert_eq!(g.y.to_bits(), e.y.to_bits(), "{variant:?} w{workers}: y {i}");
            }
        }
    }
}

/// The folded report's totals equal the `ExecReport`'s own accounting
/// *exactly* — not approximately: both read the same per-shard facts.
/// Materialized and streamed, across worker counts.
#[test]
fn metrics_reconcile_with_the_exec_report() {
    let factory = sum_factory(SumMode::Enumerated);
    let blobs = gen_blobs(2000, RegionSpec::Uniform { max: 25 }, 5);
    for workers in [1usize, 3, 8] {
        // materialized: worker-side totals only, flow side stays zero
        let report = ShardedRunner::new(metered(workers)).run(&factory, &blobs).unwrap();
        let m = report.metrics_report.as_ref().expect("metrics attached");
        let t = &m.totals;
        let ctx = format!("materialized workers {workers}");
        assert_eq!(m.workers, workers, "{ctx}");
        assert_eq!(t.shards, report.shards as u64, "{ctx}: shards");
        assert_eq!(t.regions, blobs.len() as u64, "{ctx}: regions");
        assert_eq!(t.stolen, report.steals as u64, "{ctx}: steals");
        assert_eq!(t.retries, report.retries, "{ctx}: retries");
        assert_eq!(t.faults, 0, "{ctx}: fault-free");
        assert_eq!(t.service.count, t.shards, "{ctx}: one service sample per shard");
        assert_eq!(t.queue_wait.count, t.shards, "{ctx}: one wait sample per shard");
        assert_eq!(t.busy_ns, t.service.sum_ns, "{ctx}: busy time is the service sum");
        assert_eq!(t.e2e.count, 0, "{ctx}: no submit stamps when materialized");
        assert_eq!(t.submitted_regions, 0, "{ctx}");
        assert_eq!(t.emitted_regions, 0, "{ctx}");

        // streamed: the driver lane fills the flow side
        let report = ShardedRunner::new(metered(workers).streaming(64))
            .run_stream(&factory, SliceSource::new(&blobs))
            .unwrap();
        let m = report.metrics_report.as_ref().expect("metrics attached");
        let t = &m.totals;
        let ctx = format!("streamed workers {workers}");
        assert_eq!(t.shards, report.shards as u64, "{ctx}: shards");
        assert_eq!(t.submitted_shards, t.shards, "{ctx}: every shard was submitted");
        assert_eq!(t.emitted_shards, t.shards, "{ctx}: every shard was emitted");
        assert_eq!(t.submitted_regions, blobs.len() as u64, "{ctx}");
        assert_eq!(t.emitted_regions, t.submitted_regions, "{ctx}: flow balances");
        assert_eq!(t.e2e.count, t.emitted_regions, "{ctx}: one e2e sample per region");
        assert_eq!(t.stolen, report.steals as u64, "{ctx}: steals");
        assert!(
            t.peak_in_flight >= 1 && t.peak_in_flight <= 64,
            "{ctx}: peak gauge {} within the budget",
            t.peak_in_flight
        );
        assert!(m.emit_rate() > 0.0, "{ctx}: live rate");
    }
}

/// A plan that poisons every shard index once, alternating panic/error.
fn poison_every_shard(shards: usize) -> FaultPlan {
    let mut plan = FaultPlan::new();
    for shard in 0..shards {
        plan = plan.with_shot(FaultShot {
            shard,
            worker: None,
            kind: if shard % 2 == 0 {
                FaultKind::Panic
            } else {
                FaultKind::Error
            },
            times: 1,
        });
    }
    plan
}

/// Metering a faulted run stays bit-identical to the unmetered faulted
/// run, and the fault/retry counters reconcile with the injection plan
/// and the report's own ledger: retry recovery counts one fault + one
/// retry per shot; quarantine counts the terminal failed attempt too
/// (`faults == retries + fault_table entries`).
#[test]
fn metered_faulted_runs_stay_identical_and_reconcile() {
    let blobs = gen_blobs(600, RegionSpec::Uniform { max: 16 }, 11);
    let base = ExecConfig::new(3).with_shards_per_worker(2).streaming(24);
    for streamed in [false, true] {
        let ctx = format!("retry {}", if streamed { "streamed" } else { "materialized" });
        let runner = ShardedRunner::new(base.clone());
        let clean = if streamed {
            runner
                .run_stream(&sum_factory(SumMode::Enumerated), SliceSource::new(&blobs))
                .unwrap()
        } else {
            runner.run(&sum_factory(SumMode::Enumerated), &blobs).unwrap()
        };
        let plan = poison_every_shard(clean.shards);

        let run_faulted = |cfg: ExecConfig| {
            let faulty = FaultyFactory::new(sum_factory(SumMode::Enumerated), &plan);
            let runner = ShardedRunner::new(cfg.with_fault(FaultPolicy::retry(3)));
            if streamed {
                runner.run_stream(&faulty, SliceSource::new(&blobs)).unwrap()
            } else {
                runner.run(&faulty, &blobs).unwrap()
            }
        };
        let plain = run_faulted(base.clone());
        let report = run_faulted(base.clone().with_metrics(true));
        let got = finish_sharded_outputs(SumMode::Enumerated, report.outputs);
        let want = finish_sharded_outputs(SumMode::Enumerated, plain.outputs);
        assert_outputs_bitwise(&got, &want, &ctx);
        let t = &report.metrics_report.as_ref().expect("metrics attached").totals;
        assert_eq!(t.retries, report.retries, "{ctx}: retries match the report");
        assert_eq!(t.retries, plan.injected() as u64, "{ctx}: one retry per shot");
        assert_eq!(t.faults, t.retries, "{ctx}: recovered faults == retries");
    }

    // quarantine: the terminal attempt is a fault with no retry behind it
    let clean = ShardedRunner::new(base.clone())
        .run(&sum_factory(SumMode::Enumerated), &blobs)
        .unwrap();
    let target = clean.shards / 2;
    let faulty = FaultyFactory::new(
        sum_factory(SumMode::Enumerated),
        &FaultPlan::new().panic_at(target),
    );
    let report = ShardedRunner::new(base.with_metrics(true).with_fault(FaultPolicy::Quarantine))
        .run(&faulty, &blobs)
        .unwrap();
    assert_eq!(report.faults.len(), 1, "one ledger entry");
    let t = &report.metrics_report.as_ref().unwrap().totals;
    assert_eq!(
        t.faults,
        t.retries + report.faults.len() as u64,
        "quarantine: faults = retries + fault_table entries"
    );
    assert!(
        report.fault_table().contains("injected fault"),
        "the ledger still renders"
    );
}

/// Region splitting and metering compose: outputs stay bit-identical to
/// the unmetered split run, and the flow side counts *sub*-shards.
#[test]
fn metered_split_run_is_bitwise_identical() {
    let blobs = gen_blobs(300, RegionSpec::Uniform { max: 120 }, 13);
    let factory = sum_factory(SumMode::Enumerated);
    let base = ExecConfig::new(3).streaming(48).with_max_region_items(32);
    let plain = ShardedRunner::new(base.clone())
        .run_stream(&factory, SliceSource::new(&blobs))
        .unwrap();
    assert!(plain.split_regions > 0, "the workload must actually split");
    let report = ShardedRunner::new(base.with_metrics(true))
        .run_stream(&factory, SliceSource::new(&blobs))
        .unwrap();
    assert_eq!(report.split_regions, plain.split_regions, "same cuts");
    let got = finish_sharded_outputs(SumMode::Enumerated, report.outputs);
    let want = finish_sharded_outputs(SumMode::Enumerated, plain.outputs);
    assert_outputs_bitwise(&got, &want, "metered split stream");
    let t = &report.metrics_report.as_ref().expect("metrics attached").totals;
    assert_eq!(t.shards, report.shards as u64, "shards count sub-shards");
    assert_eq!(t.submitted_regions, t.emitted_regions, "flow balances");
    assert_eq!(t.e2e.count, t.emitted_regions, "one e2e sample per part");
}

/// Lane folding is order-independent end to end: totals from independent
/// runs merge associatively, so *any* per-worker fold order the pool
/// happens to use yields the same `MetricsReport`.
#[test]
fn lane_fold_order_is_irrelevant_for_real_run_totals() {
    let factory = sum_factory(SumMode::Enumerated);
    let totals: Vec<LaneMetrics> = [1usize, 2, 4]
        .iter()
        .map(|&workers| {
            let blobs = gen_blobs(500 * workers, RegionSpec::Uniform { max: 20 }, workers as u64);
            ShardedRunner::new(metered(workers).streaming(32))
                .run_stream(&factory, SliceSource::new(&blobs))
                .unwrap()
                .metrics_report
                .expect("metrics attached")
                .totals
        })
        .collect();
    let [a, b, c] = <[LaneMetrics; 3]>::try_from(totals).unwrap();
    let mut left = a.clone(); // (a ⊕ b) ⊕ c
    left.merge(&b);
    left.merge(&c);
    let mut bc = b; // a ⊕ (b ⊕ c)
    bc.merge(&c);
    let mut right = a;
    right.merge(&bc);
    assert_eq!(left, right, "fold of real run lanes is associative");
    assert!(left.e2e.count > 0 && left.shards > 0);
}

/// The offline twin: `trace summarize` re-derives per-shard latency from
/// the artifact's Submit/Emit spans alone, and on a traced **and**
/// metered run it pairs exactly the shards the live report counted.
#[test]
fn trace_summarize_latency_section_matches_live_metrics() {
    let factory = sum_factory(SumMode::Enumerated);
    let blobs = gen_blobs(1000, RegionSpec::Uniform { max: 30 }, 23);
    let cfg = metered(3)
        .streaming(32)
        .with_trace(Some(TraceOptions { capacity: 1 << 16 }));
    let report = ShardedRunner::new(cfg)
        .run_stream(&factory, SliceSource::new(&blobs))
        .unwrap();
    let trace = report.trace.as_ref().expect("trace attached");
    assert_eq!(trace.dropped(), 0, "pairing needs the full event stream");
    let t = &report.metrics_report.as_ref().expect("metrics attached").totals;
    let text = regatta::trace::chrome::to_chrome_json(trace);
    let rendered = regatta::trace::summary::summarize(&text, 12).unwrap();
    assert!(
        rendered.contains("latency (ingest submit -> in-order emit)"),
        "{rendered}"
    );
    assert!(
        rendered.contains(&format!(
            "paired {} of {} submitted shards",
            t.emitted_shards, t.submitted_shards
        )),
        "offline pairing must match the live flow counters: {rendered}"
    );
    assert!(rendered.contains("per-shard p50"), "{rendered}");
}

/// The `--metrics` JSON artifact written by the real binary re-loads via
/// `MetricsReport::from_json`, reconciles, and `regatta metrics
/// summarize` renders it.
#[test]
fn cli_metrics_artifact_round_trips_through_summarize() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("regatta_metrics_{}.json", std::process::id()));
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_regatta"))
        .args([
            "run",
            "sum",
            "--items",
            "2000",
            "--region-max",
            "24",
            "--workers",
            "2",
            "--stream",
            "--metrics",
        ])
        .arg(&path)
        .output()
        .expect("spawn regatta");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&path).expect("artifact written");
    let report = MetricsReport::from_json(&text).expect("artifact re-loads");
    let t = &report.totals;
    assert_eq!(t.submitted_regions, 2000, "every generated region submitted");
    assert_eq!(t.emitted_regions, 2000, "every region emitted in order");
    assert_eq!(t.e2e.count, 2000, "one e2e sample per region");
    assert_eq!(report.workers, 2);

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_regatta"))
        .args(["metrics", "summarize", "--input"])
        .arg(&path)
        .output()
        .expect("spawn regatta metrics summarize");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let rendered = String::from_utf8_lossy(&out.stdout);
    assert!(rendered.contains("2000 submitted / 2000 emitted"), "{rendered}");
    assert!(rendered.contains("e2e"), "{rendered}");
    assert!(rendered.contains("p99"), "{rendered}");
    std::fs::remove_file(&path).ok();
}

/// `--progress-secs` on the real binary: at least one heartbeat line
/// (the forced end-of-stream tick), every line a single machine-parseable
/// `progress key=value ...` record, `done=1` exactly once and last, and
/// no heartbeat text ever spliced into another line (the driver owns
/// stdout until the run completes, so the `--stats` tables that follow
/// start on fresh lines).
#[test]
fn cli_progress_heartbeat_is_parseable_and_never_interleaves() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_regatta"))
        .args([
            "run",
            "sum",
            "--items",
            "4000",
            "--region-max",
            "24",
            "--workers",
            "2",
            "--stream",
            "--stats",
            "--progress-secs",
            "1",
        ])
        .output()
        .expect("spawn regatta");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let progress: Vec<&str> = stdout
        .lines()
        .filter(|l| l.starts_with("progress "))
        .collect();
    assert!(
        !progress.is_empty(),
        "a progress-enabled run prints at least the final tick:\n{stdout}"
    );
    // heartbeat text never appears mid-line
    for line in stdout.lines() {
        if let Some(at) = line.find("progress t=") {
            assert_eq!(at, 0, "heartbeat spliced into another line: {line:?}");
        }
    }
    for line in &progress {
        let mut tokens = line.split_whitespace();
        assert_eq!(tokens.next(), Some("progress"), "{line}");
        for tok in tokens {
            let (key, value) = tok
                .split_once('=')
                .unwrap_or_else(|| panic!("token {tok:?} is not key=value in {line:?}"));
            assert!(!key.is_empty() && !value.is_empty(), "{line}");
        }
    }
    let done: Vec<&&str> = progress.iter().filter(|l| l.contains("done=1")).collect();
    assert_eq!(done.len(), 1, "exactly one final tick:\n{stdout}");
    assert!(
        progress.last().unwrap().contains("done=1"),
        "the final tick is last:\n{stdout}"
    );
    // the worker table (--stats) still renders after the heartbeat
    assert!(stdout.contains("worker"), "{stdout}");
}

/// The record path of an enabled hub allocates nothing — integration
/// twin of the unit proof, through the public `metrics` API.
#[test]
#[cfg(feature = "count-allocs")]
fn enabled_hub_record_path_is_alloc_free() {
    use regatta::metrics::MetricsSpec;
    use regatta::util::alloc_count;
    let hub = MetricsSpec::new().hub();
    hub.record_shard(1, false, 1, 1); // warm the Rc + RefCell
    let before = alloc_count::thread_allocations();
    for i in 0..10_000u64 {
        hub.record_shard(4, i % 3 == 0, i, 2 * i);
        hub.record_submit(4);
        hub.record_emit(4, 3 * i);
        hub.record_stall(i);
        hub.note_in_flight(i % 128);
        hub.record_idle(i);
        hub.record_faults(i % 2, i % 2);
    }
    let lane = hub.take();
    let delta = alloc_count::thread_allocations() - before;
    assert_eq!(delta, 0, "record path allocated {delta} times");
    assert_eq!(lane.shards, 10_000);
    assert_eq!(lane.e2e.count, 40_000, "four regions per emit");
}
