//! The constant-memory proof for out-of-core streaming (ISSUE 4
//! acceptance): driver-side allocations while streaming a `.rgn` file
//! are governed by the **ingest-buffer budget**, not by file size.
//!
//! Mechanism under test (all three pieces must hold together):
//!
//! * `BlobFileSource` reads every frame through one reusable payload
//!   buffer;
//! * element containers circulate — the source takes `Vec<f32>`s from a
//!   shared `ContainerPool`, workers hand them back through
//!   `PipelineFactory::recycle_region` after each shard;
//! * the executor's in-flight budget caps how many regions exist at
//!   once, so the pool's population (and with it every driver-side
//!   allocation) has a budget-shaped high-water mark.
//!
//! The proof streams a 2k-region and a **100× larger** 200k-region
//! container through the same budget and requires the driver-thread
//! allocation delta to stay within the budget — a per-region or
//! per-shard leak would cost hundreds of thousands of allocations. The
//! same bound is then shown for the pooled synthetic generator
//! (`GenBlobSource::with_pool`), which shares the recycling contract.

#![cfg(feature = "count-allocs")]

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use regatta::coordinator::enumerate::Blob;
use regatta::exec::{
    ContainerPool, ExecConfig, PipelineFactory, ShardOutput, ShardWorker, ShardedRunner,
};
use regatta::io::{write_rgn_file, BlobFileSource};
use regatta::util::alloc_count;
use regatta::workload::regions::{GenBlobSource, RegionSpec};

const BUDGET: usize = 64;
const REGION_SIZE: usize = 4;

/// Self-deleting temp file.
struct TempFile(PathBuf);

impl TempFile {
    fn new(name: &str) -> TempFile {
        TempFile(std::env::temp_dir().join(format!(
            "regatta_memtest_{}_{name}",
            std::process::id()
        )))
    }
}

impl Drop for TempFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

/// Heap-free pipeline over `Blob` regions that returns every element
/// container to the shared pool: all driver-side allocations observed
/// around a run belong to the I/O + ingest machinery itself.
struct DrainFactory {
    pool: Arc<ContainerPool<f32>>,
}

struct DrainWorker;

impl ShardWorker for DrainWorker {
    type In = Blob;
    type Out = u32;

    fn run_shard(&mut self, shard: &[Blob]) -> Result<ShardOutput<u32>> {
        Ok(ShardOutput {
            outputs: Vec::new(), // Vec::new never allocates
            metrics: Default::default(),
            invocations: shard.iter().map(|b| b.elems.len() as u64).sum(),
        })
    }
}

impl PipelineFactory for DrainFactory {
    type In = Blob;
    type Out = u32;
    type Worker = DrainWorker;

    fn make_worker(&self, _worker_id: usize) -> Result<DrainWorker> {
        Ok(DrainWorker)
    }

    fn weight(&self, b: &Blob) -> usize {
        b.elems.len().max(1)
    }

    fn recycle_region(&self, b: Blob) {
        self.pool.put(b.elems);
    }
}

fn write_file(regions: usize, name: &str) -> TempFile {
    let tmp = TempFile::new(name);
    let stats = write_rgn_file(
        &tmp.0,
        GenBlobSource::new(
            regions * REGION_SIZE,
            RegionSpec::Fixed { size: REGION_SIZE },
            99,
        ),
    )
    .unwrap();
    assert_eq!(stats.regions as usize, regions, "{name}: sized as intended");
    tmp
}

/// Stream the whole file and return (driver-thread allocations, shards,
/// items folded) — the calling thread is the ingest driver.
fn stream_file_allocs(path: &Path) -> (u64, u64, u64) {
    let pool = Arc::new(ContainerPool::new());
    let factory = DrainFactory { pool: pool.clone() };
    let runner = ShardedRunner::new(ExecConfig::new(2).streaming(BUDGET));
    let mut folded = 0u64;
    let before = alloc_count::thread_allocations();
    let source = BlobFileSource::open(path).unwrap().with_pool(pool);
    let report = runner
        .run_stream_with(&factory, source, |r| {
            folded += r.invocations;
            Ok(())
        })
        .unwrap();
    let allocs = alloc_count::thread_allocations() - before;
    (allocs, report.shards as u64, folded)
}

#[test]
fn driver_allocations_are_bounded_by_the_budget_not_rgn_file_size() {
    let small_file = write_file(2_000, "small.rgn");
    let large_file = write_file(200_000, "large.rgn");
    // warm process-level state (thread stacks, allocator arenas) once
    let _ = stream_file_allocs(&small_file.0);
    let (small, small_shards, small_items) = stream_file_allocs(&small_file.0);
    let (large, large_shards, large_items) = stream_file_allocs(&large_file.0);
    assert_eq!(small_items as usize, 2_000 * REGION_SIZE, "every item arrived");
    assert_eq!(large_items as usize, 200_000 * REGION_SIZE, "every item arrived");
    assert!(
        large_shards >= 90 * small_shards,
        "sanity: the large run really has ~100x the shards \
         ({small_shards} vs {large_shards})"
    );
    // The acceptance bound: 100x the file adds at most one budget's
    // worth of driver-side allocations (scheduling jitter in how many
    // containers each run's pool had to mint before recycling caught
    // up). A per-region read buffer or per-frame Vec would cost ~200k
    // allocations here and fail by three orders of magnitude.
    assert!(
        large <= small + BUDGET as u64,
        "driver allocations scale with file size: {small} allocs for \
         {small_shards} shards vs {large} for {large_shards}"
    );
}

/// The synthetic generator shares the same recycling contract
/// (ISSUE 4 satellite): pooled `GenBlobSource` ingest allocations are
/// budget-bound, not stream-length-bound.
fn stream_gen_allocs(regions: usize) -> (u64, u64) {
    let pool = Arc::new(ContainerPool::new());
    let factory = DrainFactory { pool: pool.clone() };
    let runner = ShardedRunner::new(ExecConfig::new(2).streaming(BUDGET));
    let source = GenBlobSource::new(
        regions * REGION_SIZE,
        RegionSpec::Fixed { size: REGION_SIZE },
        42,
    )
    .with_pool(pool);
    let before = alloc_count::thread_allocations();
    let report = runner.run_stream_with(&factory, source, |_| Ok(())).unwrap();
    let allocs = alloc_count::thread_allocations() - before;
    (allocs, report.shards as u64)
}

#[test]
fn pooled_generator_allocations_are_bounded_by_the_budget_too() {
    let _ = stream_gen_allocs(2_000);
    let (small, small_shards) = stream_gen_allocs(2_000);
    let (large, large_shards) = stream_gen_allocs(20_000);
    assert!(
        large_shards >= 9 * small_shards,
        "sanity: ~10x the shards ({small_shards} vs {large_shards})"
    );
    assert!(
        large <= small + BUDGET as u64,
        "pooled generator allocations scale with stream length: \
         {small} vs {large}"
    );
}
