//! Fault-injection matrix: every recovery path of the sharded executor,
//! exercised deterministically (see `regatta::exec::fault`).
//!
//! The claims under test, from the fault-tolerance contract:
//!
//! 1. **Retry determinism** — with [`FaultPolicy::Retry`], a run whose
//!    shards are injected with panics/errors (a panic at *every* shard
//!    index, alternating panic/error) produces output **bit-identical**
//!    to the fault-free run, for workers 1–8, materialized and streamed,
//!    sum and taxi — and the report's retry/rebuild counts reconcile
//!    with the injected plan exactly.
//! 2. **Quarantine containment, part-granular** — a poisoned shard
//!    loses only the region whose attempt actually failed: the ledger
//!    names shard *and* in-shard part, and the surviving output is the
//!    fault-free output with exactly that one region removed, still in
//!    stream order — for workers 1–8, materialized and streamed.
//! 3. **Fail-fast attribution** — the default policy aborts with an
//!    error naming the worker and the shard in flight.
//! 4. **Watchdog** — a never-completing shard turns into a named stall
//!    diagnostic (which shards are in flight) instead of a hang; and a
//!    retry backoff *longer* than the watchdog deadline still reads as
//!    progress, never as a stall.
//! 5. **Salvage** — a byte-flipped `.rgn` container read under
//!    [`CorruptFramePolicy::Skip`] yields every uncorrupted frame
//!    bit-identically, through the executor end to end, and
//!    [`verify_rgn_file`] reports exactly the corrupted frames.
//! 6. **Degradation** — a worker whose guarded pipeline rebuild also
//!    panics retires; its shard is re-dealt untouched to a survivor and
//!    the run completes bit-identically on N−1 workers. A pool of one
//!    has no survivor and aborts by name instead.
//! 7. **Ingest/sink fault domains** — transient source-pull failures
//!    are retried under the compute budget and lose no regions; a
//!    permanent one exhausts the budget with a named error; a sink
//!    failure aborts by name and the unpublished `.tmp` sibling is
//!    removed.
//!
//! [`FaultPolicy::Retry`]: regatta::exec::FaultPolicy
//! [`ExecReport::faults`]: regatta::exec::ExecReport
//! [`CorruptFramePolicy::Skip`]: regatta::io::CorruptFramePolicy
//! [`verify_rgn_file`]: regatta::io::verify_rgn_file

use std::time::Duration;

use anyhow::Result;

use regatta::apps::sum::{finish_sharded_outputs, SumConfig, SumFactory, SumMode, SumShape};
use regatta::apps::taxi::{TaxiConfig, TaxiFactory, TaxiPair, TaxiVariant};
use regatta::coordinator::metrics::PipelineMetrics;
use regatta::exec::{
    ExecConfig, ExecReport, FaultKind, FaultPlan, FaultPolicy, FaultShot, FaultyFactory,
    KernelSpawn, PipelineFactory, ShardOutput, ShardWorker, ShardedRunner,
};
use regatta::exec::{FaultySink, FaultySource};
use regatta::io::{corrupt_frame, tmp_path, verify_rgn_file, write_rgn_file, BlobFileSource,
    CorruptFramePolicy, JsonlSink};
use regatta::prelude::Policy;
use regatta::trace::TraceOptions;
use regatta::workload::regions::{gen_blobs, RegionSpec};
use regatta::workload::source::SliceSource;
use regatta::workload::taxi::{generate, TaxiGenConfig, TaxiWorkload};

const WIDTH: usize = 8;

fn sum_factory() -> SumFactory {
    SumFactory::new(
        SumConfig {
            width: WIDTH,
            mode: SumMode::Enumerated,
            shape: SumShape::Fused,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        KernelSpawn::Native,
    )
}

fn taxi_workload() -> TaxiWorkload {
    generate(
        48,
        TaxiGenConfig {
            avg_pairs: 5,
            avg_line_len: 120,
        },
        29,
    )
}

fn taxi_factory(w: &TaxiWorkload) -> TaxiFactory {
    TaxiFactory::new(
        TaxiConfig {
            width: WIDTH,
            variant: TaxiVariant::Enumerated,
            data_cap: 512,
            signal_cap: 128,
            policy: Policy::GreedyOccupancy,
        },
        KernelSpawn::Native,
        w.text.clone(),
    )
}

fn exec(workers: usize) -> ExecConfig {
    ExecConfig::new(workers).with_shards_per_worker(2).streaming(24)
}

/// A plan that poisons every shard index once, alternating panic/error
/// so both failure manifestations cross the `catch_unwind` guard.
fn poison_every_shard(shards: usize) -> FaultPlan {
    let mut plan = FaultPlan::new();
    for shard in 0..shards {
        plan = plan.with_shot(FaultShot {
            shard,
            worker: None,
            kind: if shard % 2 == 0 {
                FaultKind::Panic
            } else {
                FaultKind::Error
            },
            times: 1,
        });
    }
    plan
}

fn assert_sums_bitwise(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for (i, ((gi, gv), (wi, wv))) in got.iter().zip(want).enumerate() {
        assert_eq!(gi, wi, "{ctx}: region id at {i}");
        assert_eq!(gv.to_bits(), wv.to_bits(), "{ctx}: region {gi}: {gv} vs {wv}");
    }
}

fn assert_pairs_bitwise(got: &[TaxiPair], want: &[TaxiPair], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.tag, w.tag, "{ctx}: pair {i}");
        assert_eq!(g.x.to_bits(), w.x.to_bits(), "{ctx}: pair {i} x");
        assert_eq!(g.y.to_bits(), w.y.to_bits(), "{ctx}: pair {i} y");
    }
}

/// Retry/rebuild accounting must reconcile with the plan exactly: one
/// retry per injected shot, one rebuild per retry (so the build count is
/// the claiming workers plus the rebuilds), nothing quarantined.
fn assert_recovery_accounting<T>(report: &ExecReport<T>, injected: usize, ctx: &str) {
    assert_eq!(report.retries, injected as u64, "{ctx}: retries == injected shots");
    assert!(report.faults.is_empty(), "{ctx}: a recovered run quarantines nothing");
    assert_eq!(
        report.pipelines_built,
        report.per_worker.len() as u64 + report.retries,
        "{ctx}: one build per claiming worker plus one per rebuild-and-rerun"
    );
    let per_worker: u64 = report.per_worker.iter().map(|w| w.retries).sum();
    assert_eq!(per_worker, report.retries, "{ctx}: per-worker retries sum to the total");
}

#[test]
fn sum_retry_is_bit_identical_with_every_shard_poisoned() {
    let blobs = gen_blobs(600, RegionSpec::Uniform { max: 16 }, 11);
    let factory = sum_factory();
    for workers in 1..=8 {
        for streamed in [false, true] {
            let ctx = format!(
                "sum workers {workers} {}",
                if streamed { "streamed" } else { "materialized" }
            );
            let runner = ShardedRunner::new(exec(workers));
            let clean = if streamed {
                runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
            } else {
                runner.run(&factory, &blobs).unwrap()
            };
            assert_eq!(clean.retries, 0, "{ctx}: fault-free baseline");
            let plan = poison_every_shard(clean.shards);
            let faulty = FaultyFactory::new(sum_factory(), &plan);
            let retry_runner = ShardedRunner::new(exec(workers).with_fault(FaultPolicy::retry(3)));
            let report = if streamed {
                retry_runner.run_stream(&faulty, SliceSource::new(&blobs)).unwrap()
            } else {
                retry_runner.run(&faulty, &blobs).unwrap()
            };
            assert_eq!(faulty.remaining(), 0, "{ctx}: every planned shot fired");
            assert_eq!(report.shards, clean.shards, "{ctx}: same shard cuts");
            assert_recovery_accounting(&report, plan.injected(), &ctx);
            let got = finish_sharded_outputs(SumMode::Enumerated, report.outputs);
            let want = finish_sharded_outputs(SumMode::Enumerated, clean.outputs);
            assert_sums_bitwise(&got, &want, &ctx);
        }
    }
}

#[test]
fn taxi_retry_is_bit_identical_with_every_shard_poisoned() {
    let w = taxi_workload();
    let factory = taxi_factory(&w);
    for workers in 1..=8 {
        for streamed in [false, true] {
            let ctx = format!(
                "taxi workers {workers} {}",
                if streamed { "streamed" } else { "materialized" }
            );
            let runner = ShardedRunner::new(exec(workers));
            let clean = if streamed {
                runner.run_stream(&factory, SliceSource::new(&w.lines)).unwrap()
            } else {
                runner.run(&factory, &w.lines).unwrap()
            };
            let plan = poison_every_shard(clean.shards);
            let faulty = FaultyFactory::new(taxi_factory(&w), &plan);
            let retry_runner = ShardedRunner::new(exec(workers).with_fault(FaultPolicy::retry(3)));
            let report = if streamed {
                retry_runner.run_stream(&faulty, SliceSource::new(&w.lines)).unwrap()
            } else {
                retry_runner.run(&faulty, &w.lines).unwrap()
            };
            assert_eq!(faulty.remaining(), 0, "{ctx}: every planned shot fired");
            assert_recovery_accounting(&report, plan.injected(), &ctx);
            assert_pairs_bitwise(&report.outputs, &clean.outputs, &ctx);
        }
    }
}

#[test]
fn single_shard_injection_sweep_recovers_each_index_in_isolation() {
    // one shot at a time: shard k alone fails (panic or error by
    // parity), recovery touches nothing else, and the report counts
    // exactly that one retry
    let blobs = gen_blobs(400, RegionSpec::Uniform { max: 20 }, 17);
    let factory = sum_factory();
    let runner = ShardedRunner::new(exec(3));
    let clean = runner.run(&factory, &blobs).unwrap();
    let want = finish_sharded_outputs(SumMode::Enumerated, clean.outputs);
    for shard in 0..clean.shards {
        let plan = if shard % 2 == 0 {
            FaultPlan::new().panic_at(shard)
        } else {
            FaultPlan::new().error_at(shard)
        };
        let faulty = FaultyFactory::new(sum_factory(), &plan);
        let retry_runner = ShardedRunner::new(exec(3).with_fault(FaultPolicy::retry(2)));
        let report = retry_runner.run(&faulty, &blobs).unwrap();
        let ctx = format!("shard {shard} poisoned");
        assert_eq!(report.retries, 1, "{ctx}: exactly one retry");
        assert_eq!(faulty.remaining(), 0, "{ctx}");
        let got = finish_sharded_outputs(SumMode::Enumerated, report.outputs);
        assert_sums_bitwise(&got, &want, &ctx);
    }
}

#[test]
fn traced_retry_run_reconciles_trace_with_report() {
    let blobs = gen_blobs(500, RegionSpec::Uniform { max: 12 }, 23);
    let clean = ShardedRunner::new(exec(3)).run(&sum_factory(), &blobs).unwrap();
    let plan = poison_every_shard(clean.shards);
    let faulty = FaultyFactory::new(sum_factory(), &plan);
    let runner = ShardedRunner::new(
        exec(3)
            .with_fault(FaultPolicy::retry(3))
            .with_trace(Some(TraceOptions::default())),
    );
    let report = runner.run(&faulty, &blobs).unwrap();
    let trace = report.trace.as_ref().expect("trace attached when configured");
    assert_eq!(trace.faults(), plan.injected() as u64, "one Fault span per shot");
    assert_eq!(trace.retries(), report.retries, "one Retry span per rebuild");
    assert_eq!(trace.shards(), report.shards as u64, "every shard still completes");
    let got = finish_sharded_outputs(SumMode::Enumerated, report.outputs);
    let want = finish_sharded_outputs(SumMode::Enumerated, clean.outputs);
    assert_sums_bitwise(&got, &want, "traced retry");
}

/// `got` must be `want` with exactly one contiguous block removed —
/// the quarantined shard's slot, and nothing else.
fn assert_one_block_removed(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert!(got.len() < want.len(), "{ctx}: quarantine must cost output");
    let missing = want.len() - got.len();
    let mut prefix = 0;
    while prefix < got.len() && got[prefix] == want[prefix] {
        prefix += 1;
    }
    let (g_tail, w_tail) = (&got[prefix..], &want[prefix + missing..]);
    assert_eq!(g_tail.len(), w_tail.len(), "{ctx}");
    for (i, (g, w)) in g_tail.iter().zip(w_tail).enumerate() {
        assert_eq!(
            (g.0, g.1.to_bits()),
            (w.0, w.1.to_bits()),
            "{ctx}: tail diverges at {i} — the gap is not one contiguous block"
        );
    }
}

#[test]
fn quarantine_drops_only_the_poisoned_part_across_worker_counts() {
    // Quarantine runs per-region slices, so the planned panic lands on
    // the target shard's first region attempt and costs exactly that
    // one region — its healthy neighbours keep their outputs. The
    // precision must hold for every pool size, materialized and
    // streamed.
    let blobs = gen_blobs(600, RegionSpec::Uniform { max: 16 }, 31);
    let factory = sum_factory();
    for workers in 1..=8 {
        for streamed in [false, true] {
            let ctx = format!(
                "part quarantine workers {workers} {}",
                if streamed { "streamed" } else { "materialized" }
            );
            let runner = ShardedRunner::new(exec(workers));
            let clean = if streamed {
                runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
            } else {
                runner.run(&factory, &blobs).unwrap()
            };
            let target = clean.shards / 2;
            let faulty = FaultyFactory::new(sum_factory(), &FaultPlan::new().panic_at(target));
            let q_runner = ShardedRunner::new(exec(workers).with_fault(FaultPolicy::Quarantine));
            let report = if streamed {
                q_runner.run_stream(&faulty, SliceSource::new(&blobs)).unwrap()
            } else {
                q_runner.run(&faulty, &blobs).unwrap()
            };
            assert_eq!(report.faults.len(), 1, "{ctx}: one entry in the ledger");
            let f = &report.faults[0];
            assert_eq!(f.shard, target, "{ctx}: the ledger names the injected shard");
            assert_eq!(
                f.part,
                Some(0),
                "{ctx}: the loss is part-granular — the shot fired on the first region attempt"
            );
            assert_eq!(f.attempts, 1, "{ctx}: quarantine gives one attempt");
            assert!(f.error.contains("injected fault"), "{ctx}: {}", f.error);
            assert_eq!(report.shards, clean.shards, "{ctx}: the slot is filled, not stalled");
            let got = finish_sharded_outputs(SumMode::Enumerated, report.outputs);
            let want = finish_sharded_outputs(SumMode::Enumerated, clean.outputs);
            assert_eq!(got.len(), want.len() - 1, "{ctx}: exactly one region lost");
            assert_one_block_removed(&got, &want, &ctx);
            let table = report.fault_table();
            assert!(table.contains("injected fault"), "{ctx}: {table}");
            assert!(table.contains("part 0"), "{ctx}: granularity column: {table}");
        }
    }
}

#[test]
fn fail_fast_names_the_worker_and_the_shard() {
    let blobs = gen_blobs(300, RegionSpec::Uniform { max: 16 }, 37);
    for streamed in [false, true] {
        let faulty = FaultyFactory::new(sum_factory(), &FaultPlan::new().panic_at(1));
        let runner = ShardedRunner::new(exec(2));
        let err = if streamed {
            runner
                .run_stream(&faulty, SliceSource::new(&blobs))
                .expect_err("fail-fast must abort")
        } else {
            runner.run(&faulty, &blobs).expect_err("fail-fast must abort")
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("shard 1"), "names the shard: {msg}");
        assert!(msg.contains("worker"), "names the worker: {msg}");
        assert!(msg.contains("injected fault"), "carries the payload: {msg}");
    }
}

/// A worker whose every shard outlasts the test watchdog: `run_shard`
/// sleeps far longer than the configured deadline, so the driver's
/// completion wait must trip and diagnose instead of hanging.
struct NeverFinishes;

impl ShardWorker for NeverFinishes {
    type In = u32;
    type Out = u32;

    fn run_shard(&mut self, shard: &[u32]) -> Result<ShardOutput<u32>> {
        std::thread::sleep(Duration::from_millis(500));
        Ok(ShardOutput {
            outputs: shard.to_vec(),
            metrics: PipelineMetrics::default(),
            invocations: 0,
        })
    }
}

impl PipelineFactory for NeverFinishes {
    type In = u32;
    type Out = u32;
    type Worker = NeverFinishes;

    fn make_worker(&self, _worker_id: usize) -> Result<NeverFinishes> {
        Ok(NeverFinishes)
    }
}

#[test]
fn watchdog_turns_a_stuck_shard_into_a_named_diagnostic() {
    use regatta::workload::source::IterSource;
    let runner = ShardedRunner::new(
        ExecConfig::new(2)
            .streaming(4)
            .with_watchdog(Duration::from_millis(50)),
    );
    let err = runner
        .run_stream(&NeverFinishes, IterSource::new(0..64u32))
        .expect_err("a stuck pool must fail, not hang");
    let msg = format!("{err:#}");
    assert!(msg.contains("watchdog"), "{msg}");
    assert!(msg.contains("in flight"), "lists the in-flight shards: {msg}");
    assert!(msg.contains("stream slot"), "names the stalled merge slot: {msg}");
}

#[test]
fn retry_backoff_longer_than_the_watchdog_still_recovers() {
    // sleep_backoff beats the pool pulse in 50ms chunks, so a 300ms
    // retry pause under a 100ms watchdog must read as progress — the
    // run recovers bit-identically instead of dying with a stall
    // diagnosis mid-backoff
    let blobs = gen_blobs(300, RegionSpec::Uniform { max: 16 }, 43);
    let clean = ShardedRunner::new(exec(1))
        .run_stream(&sum_factory(), SliceSource::new(&blobs))
        .unwrap();
    let faulty = FaultyFactory::new(sum_factory(), &FaultPlan::new().panic_at(0));
    let runner = ShardedRunner::new(
        exec(1)
            .with_fault(FaultPolicy::Retry {
                max_attempts: 3,
                backoff: Duration::from_millis(300),
            })
            .with_watchdog(Duration::from_millis(100)),
    );
    let report = runner
        .run_stream(&faulty, SliceSource::new(&blobs))
        .expect("the backoff must beat the watchdog, not trip it");
    assert_eq!(report.retries, 1, "one injected fault, one retry");
    assert_eq!(faulty.remaining(), 0);
    assert_sums_bitwise(
        &finish_sharded_outputs(SumMode::Enumerated, report.outputs),
        &finish_sharded_outputs(SumMode::Enumerated, clean.outputs),
        "backoff vs watchdog",
    );
}

#[test]
fn retry_exhaustion_fails_with_a_named_error() {
    // more shots than the budget: the whole-slice attempt and both
    // narrowing attempts on the poisoned part all fail, and the error
    // names the shard and the spent budget
    let blobs = gen_blobs(300, RegionSpec::Uniform { max: 16 }, 67);
    let faulty = FaultyFactory::new(sum_factory(), &FaultPlan::new().panic_at(1).with_times(10));
    let runner = ShardedRunner::new(exec(2).with_fault(FaultPolicy::retry(3)));
    let err = runner.run(&faulty, &blobs).expect_err("the retry budget must exhaust");
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 1 still failing after 3 attempt(s)"), "{msg}");
    assert!(msg.contains("injected fault"), "carries the root cause: {msg}");
}

#[test]
fn a_retired_workers_shard_is_redealt_and_survivors_finish_bit_identically() {
    // quarantined panic -> guarded rebuild -> rebuild shot kills that
    // too -> the worker retires, its shard is re-pushed untouched, and
    // a survivor re-runs it cleanly: bit-identical output, an empty
    // fault ledger, and exactly one worker marked dead
    let blobs = gen_blobs(600, RegionSpec::Uniform { max: 16 }, 53);
    let factory = sum_factory();
    for workers in [2, 3, 8] {
        for streamed in [false, true] {
            let ctx = format!(
                "degraded workers {workers} {}",
                if streamed { "streamed" } else { "materialized" }
            );
            let runner = ShardedRunner::new(exec(workers));
            let clean = if streamed {
                runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
            } else {
                runner.run(&factory, &blobs).unwrap()
            };
            let target = clean.shards / 2;
            let plan = FaultPlan::new().panic_at(target).panic_on_rebuild();
            let faulty = FaultyFactory::new(sum_factory(), &plan);
            let d_runner = ShardedRunner::new(exec(workers).with_fault(FaultPolicy::Quarantine));
            let report = if streamed {
                d_runner.run_stream(&faulty, SliceSource::new(&blobs)).unwrap()
            } else {
                d_runner.run(&faulty, &blobs).unwrap()
            };
            assert!(
                report.faults.is_empty(),
                "{ctx}: the re-dealt shard finishes clean, nothing quarantined: {:?}",
                report.faults
            );
            let dead: Vec<usize> =
                report.per_worker.iter().filter(|w| w.dead).map(|w| w.worker).collect();
            assert_eq!(dead.len(), 1, "{ctx}: exactly one worker retired, got {dead:?}");
            assert!(
                report.worker_table().contains("retired"),
                "{ctx}: the worker table marks the retirement"
            );
            assert_sums_bitwise(
                &finish_sharded_outputs(SumMode::Enumerated, report.outputs),
                &finish_sharded_outputs(SumMode::Enumerated, clean.outputs),
                &ctx,
            );
        }
    }
}

#[test]
fn a_pool_of_one_cannot_degrade_and_aborts_by_name() {
    let blobs = gen_blobs(300, RegionSpec::Uniform { max: 16 }, 71);
    let faulty = FaultyFactory::new(
        sum_factory(),
        &FaultPlan::new().panic_at(0).panic_on_rebuild(),
    );
    let runner = ShardedRunner::new(exec(1).with_fault(FaultPolicy::Quarantine));
    let err = runner
        .run(&faulty, &blobs)
        .expect_err("no survivor can take the retiring worker's shard");
    let msg = format!("{err:#}");
    assert!(msg.contains("no surviving worker"), "{msg}");
    assert!(msg.contains("lost its pipeline"), "{msg}");
}

#[test]
fn transient_source_faults_retry_and_lose_no_regions() {
    let blobs = gen_blobs(400, RegionSpec::Uniform { max: 16 }, 59);
    let clean = ShardedRunner::new(exec(2))
        .run_stream(&sum_factory(), SliceSource::new(&blobs))
        .unwrap();
    let plan = FaultPlan::new().source_fault_at(3).source_fault_at(11);
    let src = FaultySource::new(SliceSource::new(&blobs), &plan);
    let runner = ShardedRunner::new(exec(2).with_fault(FaultPolicy::Retry {
        max_attempts: 3,
        backoff: Duration::ZERO,
    }));
    let report = runner
        .run_stream(&sum_factory(), src)
        .expect("transient source faults are retried under the compute budget");
    assert_sums_bitwise(
        &finish_sharded_outputs(SumMode::Enumerated, report.outputs),
        &finish_sharded_outputs(SumMode::Enumerated, clean.outputs),
        "source retry",
    );
}

#[test]
fn a_permanent_source_fault_exhausts_the_retry_budget_by_name() {
    let blobs = gen_blobs(400, RegionSpec::Uniform { max: 16 }, 73);
    let plan = FaultPlan::new().source_fault_at_times(2, u32::MAX);
    let src = FaultySource::new(SliceSource::new(&blobs), &plan);
    let runner = ShardedRunner::new(exec(2).with_fault(FaultPolicy::Retry {
        max_attempts: 3,
        backoff: Duration::ZERO,
    }));
    let err = runner
        .run_stream(&sum_factory(), src)
        .expect_err("a permanent source fault must exhaust the budget");
    let msg = format!("{err:#}");
    assert!(msg.contains("ingest source still failing after 3 attempt(s)"), "{msg}");
    assert!(msg.contains("source pull 2 failed"), "carries the root cause: {msg}");

    // without a retry budget the same fault aborts on first sight
    let src = FaultySource::new(
        SliceSource::new(&blobs),
        &FaultPlan::new().source_fault_at(2),
    );
    let err = ShardedRunner::new(exec(2))
        .run_stream(&sum_factory(), src)
        .expect_err("fail-fast propagates the source fault immediately");
    assert!(format!("{err:#}").contains("source pull 2 failed"), "{err:#}");
}

#[test]
fn a_sink_fault_aborts_by_name_and_removes_the_tmp_sibling() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("regatta_sink_fault_{}.jsonl", std::process::id()));
    let tmp = tmp_path(&path);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&tmp);
    let blobs = gen_blobs(300, RegionSpec::Uniform { max: 16 }, 61);
    {
        let mut sink = FaultySink::new(
            JsonlSink::create(&path).unwrap(),
            &FaultPlan::new().sink_fault_at(0),
        );
        let err = ShardedRunner::new(exec(2))
            .run_stream_into(&sum_factory(), SliceSource::new(&blobs), &mut sink)
            .expect_err("the sink fault must abort the run");
        let msg = format!("{err:#}");
        assert!(msg.contains("result sink failed writing batch 0"), "{msg}");
    } // sink dropped unfinished: the Drop guard must clean the staging file
    assert!(!tmp.exists(), "the .tmp sibling is removed on drop");
    assert!(!path.exists(), "the final path was never published");
}

#[test]
fn skip_corrupt_reads_every_uncorrupted_frame_through_the_executor() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("regatta_fault_salvage_{}.rgn", std::process::id()));
    let blobs = gen_blobs(300, RegionSpec::Uniform { max: 16 }, 41);
    write_rgn_file(&path, SliceSource::new(&blobs)).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let corrupted = [0usize, 7, 20];
    for &f in &corrupted {
        corrupt_frame(&mut bytes, f).unwrap();
    }
    std::fs::write(&path, &bytes).unwrap();

    // `rgn verify` sees exactly the three corrupt frames
    let audit = verify_rgn_file(&path).unwrap();
    assert!(!audit.ok());
    assert_eq!(audit.corrupt_frames, corrupted.len() as u64);
    assert_eq!(audit.regions as usize, blobs.len() - corrupted.len());

    // the default policy still refuses the file, through the executor
    let strict = BlobFileSource::open(&path).unwrap();
    let err = ShardedRunner::new(exec(2))
        .run_stream(&sum_factory(), strict)
        .expect_err("corrupt frames fail hard by default");
    assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");

    // salvage mode: every uncorrupted frame, bit-identical, in order
    let intact: Vec<_> = blobs
        .iter()
        .enumerate()
        .filter(|(i, _)| !corrupted.contains(i))
        .map(|(_, b)| b.clone())
        .collect();
    let want = ShardedRunner::new(exec(2))
        .run(&sum_factory(), &intact)
        .unwrap();
    let salvaging = BlobFileSource::open(&path)
        .unwrap()
        .with_corrupt_policy(CorruptFramePolicy::Skip);
    let got = ShardedRunner::new(exec(2))
        .run_stream(&sum_factory(), salvaging)
        .unwrap();
    assert_sums_bitwise(
        &finish_sharded_outputs(SumMode::Enumerated, got.outputs),
        &finish_sharded_outputs(SumMode::Enumerated, want.outputs),
        "salvaged stream",
    );
    std::fs::remove_file(&path).unwrap();
}
