//! Intra-region sub-shard parallelism ≡ the unsplit run (see
//! `regatta::exec::split`).
//!
//! The splitting contract under test:
//!
//! 1. **Bit-identity** — with [`ExecConfig::max_region_items`] set, the
//!    fused enumerated sum's outputs are bit-for-bit identical to the
//!    unsplit single-threaded run, for workers 1–8, materialized and
//!    streamed, across thresholds and region mixes (parts are cut at
//!    ensemble boundaries and re-folded left-linear in part order, so
//!    the f64 addition sequence is replayed exactly).
//! 2. **Threshold edges** — a region exactly at the threshold is not
//!    split; 1-item regions pass through any threshold (even below the
//!    SIMD width); an all-giant stream splits every region; threshold 0
//!    is the old planner, bit for bit.
//! 3. **Order independence** — the reduction shape is a pure function of
//!    part index, never completion order: an adversarial factory whose
//!    first parts finish *last* (under stealing, workers 1–4) still
//!    folds with an order-sensitive combine to the workers-1 result.
//! 4. **Named refusal** — order-dependent stages (taxi's line parse, the
//!    two-stage sum) refuse `--max-region-items` eagerly and by name,
//!    on both the materialized and streaming paths, and the apps'
//!    single-worker inline fast path does not bypass the refusal.
//! 5. **Fault composition** — retry on a split run is still
//!    bit-identical; quarantine on a split run withholds the output row
//!    of every region that lost a part (never a partial fold passed off
//!    as a total), salvages the surviving parts into the explicit
//!    [`PartialRegion`](regatta::exec::PartialRegion) ledger, and
//!    leaves every fully-folded survivor bit-identical.
//!
//! [`ExecConfig::max_region_items`]: regatta::exec::ExecConfig

use std::rc::Rc;
use std::time::Duration;

use anyhow::Result;

use regatta::apps::sum::{
    finish_sharded_outputs, SumApp, SumConfig, SumFactory, SumMode, SumShape,
};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiFactory, TaxiVariant};
use regatta::exec::{
    ClaimMode, ExecConfig, FaultPlan, FaultPolicy, FaultyFactory, KernelSpawn, PipelineFactory,
    ShardOutput, ShardWorker, ShardedRunner, Splittability,
};
use regatta::prelude::Policy;
use regatta::runtime::kernels::KernelSet;
use regatta::workload::regions::{gen_blobs, RegionSpec};
use regatta::workload::source::SliceSource;
use regatta::workload::taxi::{generate, TaxiGenConfig, TaxiWorkload};

const WIDTH: usize = 8;

fn sum_factory(mode: SumMode, shape: SumShape) -> SumFactory {
    SumFactory::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        KernelSpawn::Native,
    )
}

fn sum_app(mode: SumMode, shape: SumShape) -> SumApp {
    SumApp::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        Rc::new(KernelSet::native(WIDTH)),
    )
}

/// Region mixes that exercise the splitter: giant regions, a mix of
/// giant and tiny, threshold-straddling sizes, and skew.
fn region_mixes() -> Vec<(u64, RegionSpec)> {
    vec![
        (1, RegionSpec::Fixed { size: 40 * WIDTH }),
        (2, RegionSpec::Fixed { size: 3 * WIDTH + 1 }),
        (3, RegionSpec::Uniform { max: 12 * WIDTH }),
        (4, RegionSpec::Skewed { max: 64 * WIDTH }),
    ]
}

fn assert_sums_bitwise(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for (i, ((gi, gv), (wi, wv))) in got.iter().zip(want).enumerate() {
        assert_eq!(gi, wi, "{ctx}: region id at {i}");
        assert_eq!(
            gv.to_bits(),
            wv.to_bits(),
            "{ctx}: region {gi} sum {gv} vs {wv}"
        );
    }
}

fn split_exec(workers: usize, max_items: usize) -> ExecConfig {
    ExecConfig::new(workers)
        .with_shards_per_worker(2)
        .streaming(64)
        .with_max_region_items(max_items)
}

// ---- bit-identity ---------------------------------------------------

#[test]
fn split_fused_sum_is_bitwise_identical_materialized_and_streamed() {
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let factory = sum_factory(SumMode::Enumerated, SumShape::Fused);
    for (seed, spec) in region_mixes() {
        let blobs = gen_blobs(4000, spec, seed);
        let single = app.run(&blobs).unwrap();
        for workers in [1usize, 2, 4, 8] {
            for max_items in [WIDTH, 5 * WIDTH] {
                let runner = ShardedRunner::new(split_exec(workers, max_items));
                for streamed in [false, true] {
                    let ctx = format!(
                        "{spec:?} seed {seed} workers {workers} max {max_items} {}",
                        if streamed { "streamed" } else { "materialized" }
                    );
                    let report = if streamed {
                        runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
                    } else {
                        runner.run(&factory, &blobs).unwrap()
                    };
                    assert_sums_bitwise(&report.outputs, &single.outputs, &ctx);
                    let oversized = blobs
                        .iter()
                        .filter(|b| b.elems.len().max(1) > max_items)
                        .count();
                    assert_eq!(report.split_regions, oversized, "{ctx}: split count");
                }
            }
        }
    }
}

#[test]
fn app_level_split_runs_match_the_plain_run() {
    // the same contract through the app front door (SumApp applies its
    // post-merge finish on top of the executor)
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(3000, RegionSpec::Skewed { max: 48 * WIDTH }, 9);
    let single = app.run(&blobs).unwrap();
    for workers in [1usize, 3, 8] {
        let exec = split_exec(workers, 2 * WIDTH);
        let sharded = app.run_sharded_with(&blobs, &exec).unwrap();
        assert_sums_bitwise(
            &sharded.outputs,
            &single.outputs,
            &format!("sharded workers {workers}"),
        );
        let streamed = app.run_streaming(SliceSource::new(&blobs), &exec).unwrap();
        assert_sums_bitwise(
            &streamed.outputs,
            &single.outputs,
            &format!("streamed workers {workers}"),
        );
    }
}

// ---- threshold edges ------------------------------------------------

#[test]
fn threshold_exactly_at_region_size_does_not_split() {
    let factory = sum_factory(SumMode::Enumerated, SumShape::Fused);
    let size = 3 * WIDTH;
    let blobs = gen_blobs(1200, RegionSpec::Fixed { size }, 41);
    let single = ShardedRunner::new(ExecConfig::new(1)).run(&factory, &blobs).unwrap();
    for streamed in [false, true] {
        // at the threshold: untouched
        let runner = ShardedRunner::new(split_exec(4, size));
        let at = if streamed {
            runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
        } else {
            runner.run(&factory, &blobs).unwrap()
        };
        assert_eq!(at.split_regions, 0, "streamed {streamed}: at-threshold regions stay whole");
        assert_sums_bitwise(&at.outputs, &single.outputs, "at threshold");
        // one item under: every region is cut
        let runner = ShardedRunner::new(split_exec(4, size - 1));
        let under = if streamed {
            runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
        } else {
            runner.run(&factory, &blobs).unwrap()
        };
        assert_eq!(
            under.split_regions,
            blobs.len(),
            "streamed {streamed}: one item under the threshold cuts every region"
        );
        assert_sums_bitwise(&under.outputs, &single.outputs, "under threshold");
    }
}

#[test]
fn one_item_regions_pass_through_any_threshold() {
    // a 1-item region can never be cut, so even a threshold below the
    // SIMD width is legal for it (the ensemble-alignment rule only
    // applies to regions that actually split)
    let factory = sum_factory(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(64, RegionSpec::Fixed { size: 1 }, 43);
    let single = ShardedRunner::new(ExecConfig::new(1)).run(&factory, &blobs).unwrap();
    for streamed in [false, true] {
        let runner = ShardedRunner::new(split_exec(3, 1));
        let report = if streamed {
            runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
        } else {
            runner.run(&factory, &blobs).unwrap()
        };
        assert_eq!(report.split_regions, 0, "streamed {streamed}");
        assert_sums_bitwise(&report.outputs, &single.outputs, "one-item regions");
    }
}

#[test]
fn all_giant_stream_splits_every_region_and_stays_bitwise() {
    let factory = sum_factory(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(6 * 16 * WIDTH, RegionSpec::Fixed { size: 16 * WIDTH }, 47);
    assert_eq!(blobs.len(), 6, "sanity: six giant regions");
    let single = ShardedRunner::new(ExecConfig::new(1)).run(&factory, &blobs).unwrap();
    for workers in [2usize, 4] {
        for streamed in [false, true] {
            let runner = ShardedRunner::new(split_exec(workers, WIDTH));
            let report = if streamed {
                runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
            } else {
                runner.run(&factory, &blobs).unwrap()
            };
            let ctx = format!("workers {workers} streamed {streamed}");
            assert_eq!(report.split_regions, blobs.len(), "{ctx}: every region cut");
            assert!(report.shards > 1, "{ctx}: parts spread across shards");
            assert_sums_bitwise(&report.outputs, &single.outputs, &ctx);
        }
    }
}

#[test]
fn threshold_zero_is_the_unsplit_planner_bit_for_bit() {
    let factory = sum_factory(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(2000, RegionSpec::Uniform { max: 10 * WIDTH }, 53);
    let plain = ShardedRunner::new(ExecConfig::new(4).with_shards_per_worker(2))
        .run(&factory, &blobs)
        .unwrap();
    let zeroed = ShardedRunner::new(
        ExecConfig::new(4).with_shards_per_worker(2).with_max_region_items(0),
    )
    .run(&factory, &blobs)
    .unwrap();
    assert_eq!(zeroed.split_regions, 0);
    assert_eq!(zeroed.shards, plain.shards, "same shard cuts");
    assert_sums_bitwise(&zeroed.outputs, &plain.outputs, "threshold 0");
}

#[test]
fn split_tagged_sum_keeps_order_and_tolerance() {
    // GlobalFold: the tagged baseline's rows pass through the merge and
    // are coalesced globally after the run, so splitting keeps the same
    // (weaker) guarantee sharding already has: exact tag order, values
    // within float-reassociation tolerance.
    let app = sum_app(SumMode::Tagged, SumShape::Fused);
    let factory = sum_factory(SumMode::Tagged, SumShape::Fused);
    let blobs = gen_blobs(1800, RegionSpec::Fixed { size: 6 * WIDTH }, 59);
    let single = app.run(&blobs).unwrap();
    for streamed in [false, true] {
        let runner = ShardedRunner::new(split_exec(4, WIDTH));
        let report = if streamed {
            runner.run_stream(&factory, SliceSource::new(&blobs)).unwrap()
        } else {
            runner.run(&factory, &blobs).unwrap()
        };
        assert_eq!(report.split_regions, blobs.len(), "every region cut");
        let got = finish_sharded_outputs(SumMode::Tagged, report.outputs);
        assert_eq!(got.len(), single.outputs.len(), "streamed {streamed}");
        for ((gi, gv), (wi, wv)) in got.iter().zip(&single.outputs) {
            assert_eq!(gi, wi, "streamed {streamed}: tag order");
            assert!(
                (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                "streamed {streamed}: tag {gi}: {gv} vs {wv}"
            );
        }
    }
}

// ---- completion-order independence ----------------------------------

/// Adversarial splittable toy: regions of `u32`s whose first part is the
/// *slowest* (a sentinel first value makes its shard sleep), so later
/// parts complete first under stealing. The per-part output folds values
/// with an order-sensitive hash, and `combine` chains part hashes with
/// another order-sensitive fold — any completion-order leakage into the
/// reduction produces a different number, not a subtle float wobble.
struct HashFactory;

#[derive(Clone)]
struct HashRegion {
    id: u64,
    vals: Vec<u32>,
}

const SLOW: u32 = 0xDEAD;

struct HashWorker;

impl ShardWorker for HashWorker {
    type In = HashRegion;
    type Out = (u64, u64);

    fn run_shard(&mut self, shard: &[HashRegion]) -> Result<ShardOutput<(u64, u64)>> {
        let mut outputs = Vec::with_capacity(shard.len());
        for r in shard {
            if r.vals.first() == Some(&SLOW) {
                std::thread::sleep(Duration::from_millis(5));
            }
            let mut h = 0u64;
            for &v in &r.vals {
                h = h.wrapping_mul(31).wrapping_add(v as u64);
            }
            outputs.push((r.id, h));
        }
        Ok(ShardOutput {
            outputs,
            metrics: Default::default(),
            invocations: shard.len() as u64,
        })
    }
}

impl PipelineFactory for HashFactory {
    type In = HashRegion;
    type Out = (u64, u64);
    type Worker = HashWorker;

    fn make_worker(&self, _worker_id: usize) -> Result<HashWorker> {
        Ok(HashWorker)
    }

    fn weight(&self, r: &HashRegion) -> usize {
        r.vals.len().max(1)
    }

    fn splittability(&self) -> Splittability {
        Splittability::RegionFold
    }

    fn split_region(&self, r: &HashRegion, max_items: usize) -> Result<Vec<HashRegion>> {
        if r.vals.len().max(1) <= max_items {
            return Ok(vec![r.clone()]);
        }
        Ok(r.vals
            .chunks(max_items)
            .map(|c| HashRegion {
                id: r.id,
                vals: c.to_vec(),
            })
            .collect())
    }

    fn combine(&self, acc: &mut (u64, u64), part: (u64, u64)) -> Result<()> {
        anyhow::ensure!(acc.0 == part.0, "fold crossed regions");
        acc.1 = acc.1.wrapping_mul(1_000_003).wrapping_add(part.1);
        Ok(())
    }
}

#[test]
fn reduction_shape_is_independent_of_completion_order() {
    // first part of every region sleeps; everything else is instant
    let regions: Vec<HashRegion> = (0..12)
        .map(|id| {
            let mut vals = vec![SLOW];
            vals.extend((0..47u32).map(|i| i * 7 + id as u32));
            HashRegion { id, vals }
        })
        .collect();
    let factory = HashFactory;
    let canonical = ShardedRunner::new(split_exec(1, 8))
        .run(&factory, &regions)
        .unwrap();
    assert_eq!(canonical.split_regions, regions.len());
    for round in 0..3 {
        for workers in [2usize, 4] {
            for streamed in [false, true] {
                let runner =
                    ShardedRunner::new(split_exec(workers, 8).with_claim(ClaimMode::Steal));
                let report = if streamed {
                    runner.run_stream(&factory, SliceSource::new(&regions)).unwrap()
                } else {
                    runner.run(&factory, &regions).unwrap()
                };
                assert_eq!(
                    report.outputs, canonical.outputs,
                    "round {round} workers {workers} streamed {streamed}: \
                     the fold followed completion order, not part order"
                );
            }
        }
    }
}

// ---- named refusal --------------------------------------------------

fn taxi_workload() -> TaxiWorkload {
    generate(
        16,
        TaxiGenConfig {
            avg_pairs: 4,
            avg_line_len: 120,
        },
        71,
    )
}

fn taxi_factory(w: &TaxiWorkload) -> TaxiFactory {
    TaxiFactory::new(
        TaxiConfig {
            width: WIDTH,
            variant: TaxiVariant::Enumerated,
            data_cap: 512,
            signal_cap: 128,
            policy: Policy::GreedyOccupancy,
        },
        KernelSpawn::Native,
        w.text.clone(),
    )
}

fn assert_refusal(err: anyhow::Error, needle: &str, ctx: &str) {
    let msg = format!("{err:#}");
    assert!(
        msg.contains("region splitting refused"),
        "{ctx}: not a refusal: {msg}"
    );
    assert!(msg.contains(needle), "{ctx}: reason missing {needle:?}: {msg}");
}

#[test]
fn taxi_refuses_splitting_by_name_even_below_threshold() {
    // eager refusal: no line is anywhere near the threshold, the config
    // alone is the error (silent ignoring would mask typos)
    let w = taxi_workload();
    let factory = taxi_factory(&w);
    let runner = ShardedRunner::new(split_exec(2, 1 << 20));
    let err = runner.run(&factory, &w.lines).unwrap_err();
    assert_refusal(err, "order-dependent", "taxi materialized");
    let err = runner
        .run_stream(&factory, SliceSource::new(&w.lines))
        .unwrap_err();
    assert_refusal(err, "order-dependent", "taxi streamed");
}

#[test]
fn two_stage_sum_refuses_splitting_by_name() {
    let factory = sum_factory(SumMode::Enumerated, SumShape::TwoStage);
    let blobs = gen_blobs(500, RegionSpec::Fixed { size: 20 * WIDTH }, 73);
    let runner = ShardedRunner::new(split_exec(2, WIDTH));
    let err = runner.run(&factory, &blobs).unwrap_err();
    assert_refusal(err, "two-stage", "two-stage materialized");
    let err = runner
        .run_stream(&factory, SliceSource::new(&blobs))
        .unwrap_err();
    assert_refusal(err, "two-stage", "two-stage streamed");
}

#[test]
fn single_worker_inline_fast_path_does_not_bypass_the_refusal() {
    // workers = 1 with default everything short-circuits to a plain run —
    // but asking for splitting must still reach the executor's refusal,
    // not silently run unsplit
    let w = taxi_workload();
    let app = TaxiApp::new(
        TaxiConfig {
            width: WIDTH,
            variant: TaxiVariant::Enumerated,
            data_cap: 512,
            signal_cap: 128,
            policy: Policy::GreedyOccupancy,
        },
        Rc::new(KernelSet::native(WIDTH)),
    );
    let exec = ExecConfig::new(1).with_max_region_items(1 << 20);
    let err = app.run_sharded_with(&w, &exec).unwrap_err();
    assert_refusal(err, "order-dependent", "taxi inline");
}

#[test]
fn threshold_below_the_simd_width_refuses_by_name() {
    // a threshold that would cut inside one ensemble breaks the exact
    // f64-addition-sequence replay, so the factory refuses it whenever a
    // region would actually split
    let factory = sum_factory(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(400, RegionSpec::Fixed { size: 5 * WIDTH }, 79);
    let runner = ShardedRunner::new(split_exec(2, WIDTH / 2));
    for streamed in [false, true] {
        let err = if streamed {
            runner
                .run_stream(&factory, SliceSource::new(&blobs))
                .unwrap_err()
        } else {
            runner.run(&factory, &blobs).unwrap_err()
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("ensemble-aligned"),
            "streamed {streamed}: {msg}"
        );
    }
}

// ---- fault composition ----------------------------------------------

#[test]
fn retry_on_a_split_run_is_still_bitwise_identical() {
    let factory = sum_factory(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(2000, RegionSpec::Skewed { max: 40 * WIDTH }, 83);
    let single = ShardedRunner::new(ExecConfig::new(1)).run(&factory, &blobs).unwrap();
    for streamed in [false, true] {
        let ctx = format!("streamed {streamed}");
        let clean_runner = ShardedRunner::new(split_exec(4, 2 * WIDTH));
        let clean = if streamed {
            clean_runner
                .run_stream(&factory, SliceSource::new(&blobs))
                .unwrap()
        } else {
            clean_runner.run(&factory, &blobs).unwrap()
        };
        assert_sums_bitwise(&clean.outputs, &single.outputs, &ctx);
        // poison every shard once: retries rebuild and rerun, the fold
        // still sees exactly one row per part
        let mut plan = FaultPlan::new();
        for shard in 0..clean.shards {
            plan = if shard % 2 == 0 {
                plan.panic_at(shard)
            } else {
                plan.error_at(shard)
            };
        }
        let faulty = FaultyFactory::new(sum_factory(SumMode::Enumerated, SumShape::Fused), &plan);
        let runner =
            ShardedRunner::new(split_exec(4, 2 * WIDTH).with_fault(FaultPolicy::retry(3)));
        let report = if streamed {
            runner.run_stream(&faulty, SliceSource::new(&blobs)).unwrap()
        } else {
            runner.run(&faulty, &blobs).unwrap()
        };
        assert_eq!(faulty.remaining(), 0, "{ctx}: every planned shot fired");
        assert_eq!(report.retries, clean.shards as u64, "{ctx}: one retry per shot");
        assert_sums_bitwise(&report.outputs, &single.outputs, &ctx);
    }
}

#[test]
fn quarantine_on_a_split_run_drops_whole_regions_only() {
    // giant regions cut into many parts across several shards: a lost
    // part must cost its region's *output row* entirely — a surviving id
    // folded from a subset of its parts would carry a partial (wrong)
    // value, which bitwise comparison against the clean run would catch.
    // The surviving parts are salvaged into the explicit partial-region
    // ledger instead, never passed off as a total
    let factory = sum_factory(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(8 * 16 * WIDTH, RegionSpec::Fixed { size: 16 * WIDTH }, 89);
    let single = ShardedRunner::new(ExecConfig::new(1)).run(&factory, &blobs).unwrap();
    for streamed in [false, true] {
        let ctx = format!("streamed {streamed}");
        let clean_runner = ShardedRunner::new(split_exec(3, WIDTH));
        let clean = if streamed {
            clean_runner
                .run_stream(&factory, SliceSource::new(&blobs))
                .unwrap()
        } else {
            clean_runner.run(&factory, &blobs).unwrap()
        };
        let target = clean.shards / 2;
        let faulty = FaultyFactory::new(
            sum_factory(SumMode::Enumerated, SumShape::Fused),
            &FaultPlan::new().panic_at(target),
        );
        let runner = ShardedRunner::new(split_exec(3, WIDTH).with_fault(FaultPolicy::Quarantine));
        let report = if streamed {
            runner.run_stream(&faulty, SliceSource::new(&blobs)).unwrap()
        } else {
            runner.run(&faulty, &blobs).unwrap()
        };
        assert_eq!(report.faults.len(), 1, "{ctx}: one ledger entry");
        assert_eq!(report.faults[0].shard, target, "{ctx}: names the shard");
        assert!(
            report.outputs.len() < single.outputs.len(),
            "{ctx}: quarantine must cost at least one region"
        );
        // the regions missing from the output are exactly the ones in
        // the salvage ledger: lost parts named, surviving parts kept as
        // partial aggregates, and never also emitted as an output row
        assert_eq!(
            report.partial_regions.len(),
            single.outputs.len() - report.outputs.len(),
            "{ctx}: one ledger entry per region withheld from the output"
        );
        for p in &report.partial_regions {
            assert!(!p.lost.is_empty(), "{ctx}: region {} lost no part", p.region);
            assert!(
                p.lost.len() < p.of as usize,
                "{ctx}: region {} ({} parts) salvaged nothing",
                p.region,
                p.of
            );
            assert!(!p.salvaged.is_empty(), "{ctx}: region {} has no salvaged runs", p.region);
            assert!(
                report.outputs.iter().all(|(gi, _)| *gi != p.region),
                "{ctx}: region {} is both salvaged and emitted",
                p.region
            );
        }
        // every surviving region is bit-identical to the clean run — no
        // id appears with a partial fold, and stream order holds
        let mut want = single.outputs.iter();
        for (i, (gi, gv)) in report.outputs.iter().enumerate() {
            let (_, wv) = want
                .by_ref()
                .find(|(wi, _)| wi == gi)
                .unwrap_or_else(|| panic!("{ctx}: output {i} id {gi} unknown or out of order"));
            assert_eq!(
                gv.to_bits(),
                wv.to_bits(),
                "{ctx}: region {gi} survived with a partial fold"
            );
        }
    }
}
