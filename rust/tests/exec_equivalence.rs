//! Sharded execution ≡ single-threaded execution.
//!
//! The L3.5 executor's contract (see `regatta::exec` module docs): for
//! region-local pipelines, sharding at region boundaries changes *nothing
//! observable* — outputs are bit-for-bit identical and in the same order
//! for every worker count, because (1) enumerated ensembles never mix
//! parents, and (2) per-region state resets at `RegionBegin`. This suite
//! pins that down across seeded random region mixes and workers 1–8, and
//! checks the weaker order-only guarantee for the lane-mixing tagged mode.

use std::rc::Rc;

use regatta::apps::sum::{reference_sums, SumApp, SumConfig, SumMode, SumShape};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiVariant};
use regatta::exec::{ExecConfig, ShardPolicy};
use regatta::prelude::Policy;
use regatta::runtime::kernels::KernelSet;
use regatta::workload::regions::{gen_blobs, RegionSpec};
use regatta::workload::taxi::{generate, TaxiGenConfig, TaxiWorkload};

const WIDTH: usize = 8;

fn sum_app(mode: SumMode, shape: SumShape) -> SumApp {
    SumApp::new(
        SumConfig {
            width: WIDTH,
            mode,
            shape,
            data_cap: 256,
            signal_cap: 64,
            ..Default::default()
        },
        Rc::new(KernelSet::native(WIDTH)),
    )
}

fn region_mixes() -> Vec<(u64, RegionSpec)> {
    vec![
        (1, RegionSpec::Fixed { size: 1 }),
        (2, RegionSpec::Fixed { size: 17 }),
        (3, RegionSpec::Fixed { size: WIDTH }),
        (4, RegionSpec::Fixed { size: 3 * WIDTH + 1 }),
        (5, RegionSpec::Uniform { max: 5 }),
        (6, RegionSpec::Uniform { max: 40 }),
        (7, RegionSpec::Uniform { max: 200 }),
    ]
}

fn assert_sums_bitwise(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for (i, ((gi, gv), (wi, wv))) in got.iter().zip(want).enumerate() {
        assert_eq!(gi, wi, "{ctx}: region id at {i}");
        assert_eq!(
            gv.to_bits(),
            wv.to_bits(),
            "{ctx}: region {gi} sum {gv} vs {wv}"
        );
    }
}

#[test]
fn sharded_sum_is_bitwise_identical_for_workers_1_to_8() {
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    for (seed, spec) in region_mixes() {
        let blobs = gen_blobs(2000, spec, seed);
        let single = app.run(&blobs).unwrap();
        assert_sums_bitwise(
            &single.outputs,
            &reference_sums_close(&blobs, &single.outputs),
            "sanity",
        );
        for workers in 1..=8 {
            let sharded = app.run_sharded(&blobs, workers).unwrap();
            assert_sums_bitwise(
                &sharded.outputs,
                &single.outputs,
                &format!("{spec:?} seed {seed} workers {workers}"),
            );
            assert_eq!(
                sharded.invocations, single.invocations,
                "{spec:?} workers {workers}: kernel invocations"
            );
        }
    }
}

/// The single run itself must agree with the f64 reference (tolerance);
/// returns the single outputs so the bitwise helper can reuse them.
fn reference_sums_close(
    blobs: &[regatta::prelude::Blob],
    got: &[(u64, f64)],
) -> Vec<(u64, f64)> {
    let want = reference_sums(blobs, 0.0);
    assert_eq!(got.len(), want.len());
    for ((gi, gv), (wi, wv)) in got.iter().zip(&want) {
        assert_eq!(gi, wi);
        assert!(
            (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
            "region {gi}: {gv} vs reference {wv}"
        );
    }
    got.to_vec()
}

#[test]
fn sharded_two_stage_sum_is_bitwise_identical() {
    let app = sum_app(SumMode::Enumerated, SumShape::TwoStage);
    let blobs = gen_blobs(1500, RegionSpec::Uniform { max: 30 }, 11);
    let single = app.run(&blobs).unwrap();
    for workers in [1usize, 3, 8] {
        let sharded = app.run_sharded(&blobs, workers).unwrap();
        assert_sums_bitwise(
            &sharded.outputs,
            &single.outputs,
            &format!("two-stage workers {workers}"),
        );
    }
}

#[test]
fn more_shards_than_workers_stays_bitwise_identical() {
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(2000, RegionSpec::Uniform { max: 50 }, 12);
    let single = app.run(&blobs).unwrap();
    for (workers, spw) in [(2usize, 4usize), (3, 3), (8, 2)] {
        let exec = ExecConfig::new(workers).with_shards_per_worker(spw);
        let sharded = app.run_sharded_with(&blobs, &exec).unwrap();
        assert_sums_bitwise(
            &sharded.outputs,
            &single.outputs,
            &format!("workers {workers} x {spw} shards"),
        );
    }
}

#[test]
fn one_worker_metrics_match_single_run_exactly() {
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    let blobs = gen_blobs(1000, RegionSpec::Uniform { max: 25 }, 13);
    let single = app.run(&blobs).unwrap();
    // `workers = 1` with the default policy short-circuits to a plain run;
    // cap the plan at one shard while keeping shards_per_worker > 1 so the
    // stream really goes through plan → pool → merge and we compare the
    // full sharded path against the plain run.
    let mut exec = ExecConfig::new(1);
    exec.shard = ShardPolicy {
        shards_per_worker: 2,
        max_shards: 1,
        min_shard_items: 1,
    };
    let sharded = app.run_sharded_with(&blobs, &exec).unwrap();
    assert_sums_bitwise(&sharded.outputs, &single.outputs, "pooled single shard");
    let (sm, hm) = (&single.metrics, &sharded.metrics);
    assert_eq!(sm.nodes.len(), hm.nodes.len());
    assert_eq!(sm.idle_polls, hm.idle_polls);
    for ((sn, s), (hn, h)) in sm.nodes.iter().zip(&hm.nodes) {
        assert_eq!(sn, hn, "node order");
        assert_eq!(s.width, h.width, "{sn}: width");
        assert_eq!(s.firings, h.firings, "{sn}: firings");
        assert_eq!(s.ensembles, h.ensembles, "{sn}: ensembles");
        assert_eq!(s.full_ensembles, h.full_ensembles, "{sn}: full ensembles");
        assert_eq!(s.items, h.items, "{sn}: items");
        assert_eq!(s.signals_consumed, h.signals_consumed, "{sn}: signals in");
        assert_eq!(s.signals_emitted, h.signals_emitted, "{sn}: signals out");
        assert_eq!(s.ensemble_hist, h.ensemble_hist, "{sn}: histogram");
    }
}

#[test]
fn sharded_tagged_sum_keeps_order_and_tolerance() {
    // The dense tagged baseline deliberately packs lanes across region
    // boundaries, so sharding changes ensemble grouping: order and ids
    // must hold exactly, values within float-reassociation tolerance.
    let app = sum_app(SumMode::Tagged, SumShape::Fused);
    let blobs = gen_blobs(1200, RegionSpec::Fixed { size: 13 }, 21);
    let want = reference_sums(&blobs, 0.0);
    for workers in [1usize, 2, 5, 8] {
        let sharded = app.run_sharded(&blobs, workers).unwrap();
        assert_eq!(sharded.outputs.len(), want.len(), "workers {workers}");
        for ((gi, gv), (wi, wv)) in sharded.outputs.iter().zip(&want) {
            assert_eq!(gi, wi, "workers {workers}: tag order");
            assert!(
                (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                "workers {workers}: tag {gi}: {gv} vs {wv}"
            );
        }
    }
}

fn taxi_app(variant: TaxiVariant) -> TaxiApp {
    TaxiApp::new(
        TaxiConfig {
            width: WIDTH,
            variant,
            data_cap: 512,
            signal_cap: 128,
            policy: Policy::GreedyOccupancy,
        },
        Rc::new(KernelSet::native(WIDTH)),
    )
}

fn taxi_workload() -> TaxiWorkload {
    generate(
        24,
        TaxiGenConfig {
            avg_pairs: 6,
            avg_line_len: 160,
        },
        77,
    )
}

#[test]
fn sharded_taxi_is_bitwise_identical_for_workers_1_to_8() {
    let w = taxi_workload();
    for variant in TaxiVariant::all() {
        let app = taxi_app(variant);
        let single = app.run(&w).unwrap();
        assert_eq!(single.pairs.len(), w.total_pairs, "{variant:?}: sanity");
        for workers in 1..=8 {
            let sharded = app.run_sharded(&w, workers).unwrap();
            assert_eq!(
                sharded.pairs.len(),
                single.pairs.len(),
                "{variant:?} workers {workers}: pair count"
            );
            for (i, (g, e)) in sharded.pairs.iter().zip(&single.pairs).enumerate() {
                assert_eq!(g.tag, e.tag, "{variant:?} workers {workers}: tag at {i}");
                assert_eq!(
                    g.x.to_bits(),
                    e.x.to_bits(),
                    "{variant:?} workers {workers}: x at {i}"
                );
                assert_eq!(
                    g.y.to_bits(),
                    e.y.to_bits(),
                    "{variant:?} workers {workers}: y at {i}"
                );
            }
        }
    }
}

#[test]
fn empty_and_tiny_streams_shard_cleanly() {
    let app = sum_app(SumMode::Enumerated, SumShape::Fused);
    // tiny: fewer regions than workers
    let blobs = gen_blobs(5, RegionSpec::Fixed { size: 2 }, 31);
    let single = app.run(&blobs).unwrap();
    let sharded = app.run_sharded(&blobs, 8).unwrap();
    assert_sums_bitwise(&sharded.outputs, &single.outputs, "tiny stream");
    // degenerate: all-empty regions
    let empties: Vec<regatta::prelude::Blob> = (0..4)
        .map(|i| regatta::prelude::Blob::from_vec(i, vec![]))
        .collect();
    let single = app.run(&empties).unwrap();
    let sharded = app.run_sharded(&empties, 3).unwrap();
    assert_sums_bitwise(&sharded.outputs, &single.outputs, "empty regions");
}
