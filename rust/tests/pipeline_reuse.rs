//! Reset-not-rebuild correctness: a persistent worker pipeline
//! ([`SumPipeline`]/[`TaxiPipeline`]), reset between shards, must be
//! observationally identical — outputs *and* per-shard metrics — to
//! building a fresh pipeline for every shard (the PR 1 single-threaded
//! oracle). The shard sequences deliberately include empty shards,
//! shards larger than every previous one (the source-capacity regrowth
//! path), and tagged-mode streams whose per-tag state would leak across
//! shards if reset missed it.
//!
//! The executor half: `ExecReport::pipelines_built` must equal the
//! number of workers that claimed a shard — never the shard count — for
//! materialized and streamed runs across workers 1–8 and every app
//! mode, with merged outputs still matching the single-run oracle.

use std::rc::Rc;

use regatta::apps::sum::{
    finish_sharded_outputs, SumApp, SumConfig, SumFactory, SumMode, SumPipeline, SumShape,
};
use regatta::apps::taxi::{TaxiApp, TaxiConfig, TaxiFactory, TaxiPipeline, TaxiVariant};
use regatta::coordinator::metrics::PipelineMetrics;
use regatta::exec::{ExecConfig, ExecReport, KernelSpawn, ShardedRunner};
use regatta::prelude::{Blob, Policy};
use regatta::runtime::kernels::KernelSet;
use regatta::workload::regions::{gen_blobs, RegionSpec};
use regatta::workload::source::SliceSource;
use regatta::workload::taxi::{generate, TaxiGenConfig, TaxiWorkload};

const WIDTH: usize = 8;

fn sum_cfg(mode: SumMode, shape: SumShape) -> SumConfig {
    SumConfig {
        width: WIDTH,
        mode,
        shape,
        data_cap: 256,
        signal_cap: 64,
        ..Default::default()
    }
}

fn taxi_cfg(variant: TaxiVariant) -> TaxiConfig {
    TaxiConfig {
        width: WIDTH,
        variant,
        data_cap: 512,
        signal_cap: 128,
        policy: Policy::GreedyOccupancy,
    }
}

/// Deterministic irregular shard cuts over `total` regions: empty
/// shards, a spread of small/medium/large sizes, and (by construction
/// below) shards that outsize every earlier one.
fn shard_sizes(seed: u64, total: usize) -> Vec<usize> {
    let mut s = seed | 1;
    let mut step = || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s
    };
    let mut sizes = Vec::new();
    let mut used = 0usize;
    while used < total {
        let r = step();
        let pick = match r % 7 {
            0 => 0, // empty shard: reset → feed nothing → drain nothing
            1..=3 => (r / 7 % 5) as usize + 1,
            4 | 5 => (r / 7 % 40) as usize + 10,
            _ => (r / 7 % 200) as usize + 50,
        };
        let pick = pick.min(total - used);
        sizes.push(pick);
        used += pick;
    }
    sizes
}

fn assert_metrics_equal(got: &PipelineMetrics, want: &PipelineMetrics, ctx: &str) {
    assert_eq!(got.idle_polls, want.idle_polls, "{ctx}: idle polls");
    assert_eq!(got.nodes.len(), want.nodes.len(), "{ctx}: node count");
    for ((gn, g), (wn, w)) in got.nodes.iter().zip(&want.nodes) {
        assert_eq!(gn, wn, "{ctx}: node order");
        assert_eq!(g.firings, w.firings, "{ctx}/{gn}: firings");
        assert_eq!(g.ensembles, w.ensembles, "{ctx}/{gn}: ensembles");
        assert_eq!(g.full_ensembles, w.full_ensembles, "{ctx}/{gn}: full");
        assert_eq!(g.items, w.items, "{ctx}/{gn}: items");
        assert_eq!(g.signals_consumed, w.signals_consumed, "{ctx}/{gn}: sig in");
        assert_eq!(g.signals_emitted, w.signals_emitted, "{ctx}/{gn}: sig out");
        assert_eq!(g.ensemble_hist, w.ensemble_hist, "{ctx}/{gn}: histogram");
    }
}

fn assert_sums_bitwise(got: &[(u64, f64)], want: &[(u64, f64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output count");
    for ((gi, gv), (wi, wv)) in got.iter().zip(want) {
        assert_eq!(gi, wi, "{ctx}: region id");
        assert_eq!(gv.to_bits(), wv.to_bits(), "{ctx}: region {gi}");
    }
}

#[test]
fn reused_sum_pipeline_is_bit_identical_to_fresh_builds_across_shard_sequences() {
    let shapes = [
        (SumMode::Enumerated, SumShape::Fused),
        (SumMode::Enumerated, SumShape::TwoStage),
        (SumMode::Tagged, SumShape::Fused),
    ];
    for (mode, shape) in shapes {
        for (seed, spec) in [
            (31u64, RegionSpec::Uniform { max: 30 }),
            (32, RegionSpec::Fixed { size: WIDTH }),
            (33, RegionSpec::Skewed { max: 80 }),
        ] {
            let cfg = sum_cfg(mode, shape);
            let app = SumApp::new(cfg, Rc::new(KernelSet::native(WIDTH)));
            let blobs = gen_blobs(3000, spec, seed);
            let mut reused = SumPipeline::build(cfg, Rc::new(KernelSet::native(WIDTH)));
            let mut off = 0usize;
            for (k, size) in shard_sizes(seed * 77, blobs.len()).into_iter().enumerate() {
                let shard = &blobs[off..off + size];
                off += size;
                let ctx = format!("{mode:?}/{shape:?} {spec:?} shard {k} ({size} regions)");
                let fresh = app.run(shard).unwrap(); // fresh build: the oracle
                let (outputs, metrics) = reused.run_shard(shard).unwrap();
                assert_sums_bitwise(&outputs, &fresh.outputs, &ctx);
                assert_metrics_equal(&metrics, &fresh.metrics, &ctx);
            }
            assert_eq!(off, blobs.len());
        }
    }
}

#[test]
fn capacity_regrows_when_a_shard_outsizes_every_previous_one() {
    // source capacity is retargeted per shard: after tiny shards, a
    // shard larger than all predecessors must grow the ring and still be
    // bit-identical to a fresh build (then shrink back logically)
    let cfg = sum_cfg(SumMode::Enumerated, SumShape::Fused);
    let app = SumApp::new(cfg, Rc::new(KernelSet::native(WIDTH)));
    // gen_blobs counts ITEMS: fixed 6-item regions → exactly 2000
    // regions, comfortably covering the 1564 the cut list consumes
    let blobs = gen_blobs(12000, RegionSpec::Fixed { size: 6 }, 41);
    let mut reused = SumPipeline::build(cfg, Rc::new(KernelSet::native(WIDTH)));
    let mut off = 0usize;
    for (k, size) in [1usize, 0, 3, 50, 2, 400, 7, 1100, 1].into_iter().enumerate() {
        let shard = &blobs[off..off + size];
        off += size;
        let ctx = format!("regrowth shard {k} ({size} regions)");
        let fresh = app.run(shard).unwrap();
        let (outputs, metrics) = reused.run_shard(shard).unwrap();
        assert_sums_bitwise(&outputs, &fresh.outputs, &ctx);
        assert_metrics_equal(&metrics, &fresh.metrics, &ctx);
    }
}

#[test]
fn tagged_mode_state_is_provably_cleared_between_shards() {
    // tags repeat across shards: any per-tag accumulator carryover
    // would surface as extra (or inflated) entries vs the fresh oracle
    let blobs: Vec<Blob> = (0..60)
        .map(|i| Blob::from_vec(i % 5, vec![1.0 + i as f32; 7]))
        .collect();
    let cfg = sum_cfg(SumMode::Tagged, SumShape::Fused);
    let app = SumApp::new(cfg, Rc::new(KernelSet::native(WIDTH)));
    let mut reused = SumPipeline::build(cfg, Rc::new(KernelSet::native(WIDTH)));
    for (k, shard) in blobs.chunks(9).enumerate() {
        let ctx = format!("tagged shard {k}");
        let fresh = app.run(shard).unwrap();
        let (outputs, metrics) = reused.run_shard(shard).unwrap();
        assert_sums_bitwise(&outputs, &fresh.outputs, &ctx);
        assert_metrics_equal(&metrics, &fresh.metrics, &ctx);
    }
}

#[test]
fn reused_taxi_pipeline_is_bit_identical_to_fresh_builds() {
    let w = generate(
        40,
        TaxiGenConfig {
            avg_pairs: 6,
            avg_line_len: 180,
        },
        51,
    );
    for variant in TaxiVariant::all() {
        let cfg = taxi_cfg(variant);
        let app = TaxiApp::new(cfg, Rc::new(KernelSet::native(WIDTH)));
        let mut reused =
            TaxiPipeline::build(cfg, Rc::new(KernelSet::native(WIDTH)), w.text.clone());
        let mut off = 0usize;
        for (k, size) in shard_sizes(91, w.lines.len()).into_iter().enumerate() {
            let shard = &w.lines[off..off + size];
            off += size;
            let ctx = format!("{variant:?} shard {k} ({size} lines)");
            let shard_w = TaxiWorkload {
                text: w.text.clone(),
                lines: shard.to_vec(),
                total_pairs: 0,
            };
            let fresh = app.run(&shard_w).unwrap(); // fresh build: the oracle
            let (pairs, metrics) = reused.run_shard(shard).unwrap();
            assert_eq!(pairs.len(), fresh.pairs.len(), "{ctx}");
            for (g, e) in pairs.iter().zip(&fresh.pairs) {
                assert_eq!(g.tag, e.tag, "{ctx}");
                assert_eq!(g.x.to_bits(), e.x.to_bits(), "{ctx}");
                assert_eq!(g.y.to_bits(), e.y.to_bits(), "{ctx}");
            }
            assert_metrics_equal(&metrics, &fresh.metrics, &ctx);
        }
    }
}

/// The executor proof shared by the sum and taxi halves below: builds
/// scale with claiming workers, never shards.
fn assert_builds_equal_workers<T>(report: &ExecReport<T>, workers: usize, ctx: &str) {
    assert!(!report.per_worker.is_empty(), "{ctx}: no worker ran");
    assert_eq!(
        report.pipelines_built,
        report.per_worker.len() as u64,
        "{ctx}: builds must equal claiming workers"
    );
    assert!(
        report.per_worker.len() <= workers,
        "{ctx}: more claimants than workers"
    );
    for w in &report.per_worker {
        assert_eq!(
            w.pipelines_built, 1,
            "{ctx}: worker {} rebuilt its pipeline ({} builds over {} shards)",
            w.worker, w.pipelines_built, w.shards
        );
    }
    if report.shards > workers {
        assert!(
            (report.pipelines_built as usize) < report.shards,
            "{ctx}: builds ({}) should not scale with shards ({})",
            report.pipelines_built,
            report.shards
        );
    }
}

#[test]
fn exec_report_proves_builds_equal_workers_for_all_sum_modes() {
    let shapes = [
        (SumMode::Enumerated, SumShape::Fused),
        (SumMode::Enumerated, SumShape::TwoStage),
        (SumMode::Tagged, SumShape::Fused),
    ];
    let blobs = gen_blobs(2500, RegionSpec::Uniform { max: 25 }, 61);
    for (mode, shape) in shapes {
        let cfg = sum_cfg(mode, shape);
        let app = SumApp::new(cfg, Rc::new(KernelSet::native(WIDTH)));
        let single = app.run(&blobs).unwrap();
        let factory = SumFactory::new(cfg, KernelSpawn::Native);
        for workers in 1..=8 {
            let exec = ExecConfig::new(workers).with_shards_per_worker(3).streaming(64);
            for streamed in [false, true] {
                let ctx = format!(
                    "{mode:?}/{shape:?} workers {workers} {}",
                    if streamed { "streamed" } else { "materialized" }
                );
                let report = if streamed {
                    ShardedRunner::new(exec.clone())
                        .run_stream(&factory, SliceSource::new(&blobs))
                        .unwrap()
                } else {
                    ShardedRunner::new(exec.clone()).run(&factory, &blobs).unwrap()
                };
                assert_builds_equal_workers(&report, workers, &ctx);
                let outputs = finish_sharded_outputs(mode, report.outputs);
                match mode {
                    // enumerated: bit-identical to the single-run oracle
                    SumMode::Enumerated => assert_sums_bitwise(&outputs, &single.outputs, &ctx),
                    // tagged: sharding regroups lanes — order + tolerance
                    SumMode::Tagged => {
                        assert_eq!(outputs.len(), single.outputs.len(), "{ctx}");
                        for ((gi, gv), (wi, wv)) in outputs.iter().zip(&single.outputs) {
                            assert_eq!(gi, wi, "{ctx}");
                            assert!(
                                (gv - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                                "{ctx}: tag {gi}: {gv} vs {wv}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn exec_report_proves_builds_equal_workers_for_all_taxi_variants() {
    let w = generate(
        32,
        TaxiGenConfig {
            avg_pairs: 6,
            avg_line_len: 160,
        },
        71,
    );
    for variant in TaxiVariant::all() {
        let cfg = taxi_cfg(variant);
        let app = TaxiApp::new(cfg, Rc::new(KernelSet::native(WIDTH)));
        let single = app.run(&w).unwrap();
        let factory = TaxiFactory::new(cfg, KernelSpawn::Native, w.text.clone());
        for workers in 1..=8 {
            let exec = ExecConfig::new(workers).with_shards_per_worker(2).streaming(16);
            for streamed in [false, true] {
                let ctx = format!(
                    "{variant:?} workers {workers} {}",
                    if streamed { "streamed" } else { "materialized" }
                );
                let report = if streamed {
                    ShardedRunner::new(exec.clone())
                        .run_stream(&factory, SliceSource::new(&w.lines))
                        .unwrap()
                } else {
                    ShardedRunner::new(exec.clone()).run(&factory, &w.lines).unwrap()
                };
                assert_builds_equal_workers(&report, workers, &ctx);
                assert_eq!(report.outputs.len(), single.pairs.len(), "{ctx}");
                for (g, e) in report.outputs.iter().zip(&single.pairs) {
                    assert_eq!(g.tag, e.tag, "{ctx}");
                    assert_eq!(g.x.to_bits(), e.x.to_bits(), "{ctx}");
                    assert_eq!(g.y.to_bits(), e.y.to_bits(), "{ctx}");
                }
            }
        }
    }
}
