//! Runtime integration: AOT artifacts → PJRT → numbers.
//!
//! Every kernel is loaded from `artifacts/` (requires `make artifacts`),
//! executed through the XLA backend, and checked against the native Rust
//! mirror — which pytest has already checked against the Pallas kernels,
//! closing the three-way equivalence loop.
//!
//! Every test here is `#[ignore]`d by default: they need the AOT artifact
//! directory (`make artifacts`, which needs JAX) **and** a real PJRT
//! runtime (the workspace links an offline `xla` stub unless the real
//! xla-rs bindings are swapped in — see rust/vendor/xla). Run them with
//! `cargo test -- --ignored` in a fully provisioned environment; tier-1
//! stays green without one.

use std::rc::Rc;

use regatta::runtime::kernels::{Backend, KernelSet};
use regatta::runtime::{native, ArtifactStore, Engine, KernelName};
use regatta::util::prng::Prng;

fn engine() -> Engine {
    let store = ArtifactStore::discover().expect("run `make artifacts` first");
    Engine::new(store).expect("PJRT CPU client")
}

fn xla_set(engine: &Engine, width: usize) -> Rc<KernelSet> {
    Rc::new(KernelSet::xla(engine, width).expect("compile kernels"))
}

fn rand_ensemble(rng: &mut Prng, w: usize) -> (Vec<f32>, Vec<i32>) {
    let vals = (0..w).map(|_| rng.range_f32(-10.0, 10.0)).collect();
    let mask = (0..w).map(|_| i32::from(rng.chance(0.7))).collect();
    (vals, mask)
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn manifest_lists_expected_widths_and_kernels() {
    let store = ArtifactStore::discover().unwrap();
    let m = store.manifest();
    assert!(m.widths.contains(&128), "widths {:?}", m.widths);
    assert!(m.widths.contains(&32));
    assert_eq!(m.window_len, native::WINDOW_LEN);
    assert!((m.scale as f32 - native::SCALE).abs() < 1e-6);
    for k in KernelName::all() {
        assert!(
            m.entries.iter().any(|e| e == k.stem()),
            "missing {}",
            k.stem()
        );
        store.path_for(k, 128).unwrap();
    }
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn missing_width_is_a_clean_error() {
    let store = ArtifactStore::discover().unwrap();
    let err = store.path_for(KernelName::SumRegion, 999).unwrap_err();
    assert!(err.to_string().contains("999"), "{err}");
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn filter_scale_xla_matches_native() {
    let eng = engine();
    let ks = xla_set(&eng, 32);
    assert_eq!(ks.backend(), Backend::Xla);
    let mut rng = Prng::new(1);
    for _ in 0..5 {
        let (vals, mask) = rand_ensemble(&mut rng, 32);
        let (gv, gm) = ks.filter_scale(&vals, &mask, 0.5).unwrap();
        let (ev, em) = native::filter_scale(&vals, &mask, 0.5);
        assert_eq!(gm, em);
        for (a, b) in gv.iter().zip(&ev) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn sum_kernels_xla_match_native() {
    let eng = engine();
    let ks = xla_set(&eng, 32);
    let mut rng = Prng::new(2);
    for _ in 0..5 {
        let (vals, mask) = rand_ensemble(&mut rng, 32);
        let (gs, gc) = ks.masked_sum(&vals, &mask).unwrap();
        let (es, ec) = native::masked_sum(&vals, &mask);
        assert_eq!(gc, ec);
        assert!((gs - es).abs() < 1e-3, "{gs} vs {es}");

        let (gs, gk) = ks.sum_region(&vals, &mask, -1.0).unwrap();
        let (es, ek) = native::sum_region(&vals, &mask, -1.0);
        assert_eq!(gk, ek);
        assert!((gs - es).abs() < 1e-3, "{gs} vs {es}");
    }
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn segmented_sum_xla_matches_native() {
    let eng = engine();
    let ks = xla_set(&eng, 32);
    let mut rng = Prng::new(3);
    for _ in 0..5 {
        let (vals, mask) = rand_ensemble(&mut rng, 32);
        let seg: Vec<i32> = (0..32).map(|_| rng.below(32) as i32).collect();
        let (gs, gc) = ks.segmented_sum(&vals, &seg, &mask).unwrap();
        let (es, ec) = native::segmented_sum(&vals, &seg, &mask);
        assert_eq!(gc, ec);
        for (a, b) in gs.iter().zip(&es) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn char_kernels_xla_match_native() {
    let eng = engine();
    let ks = xla_set(&eng, 32);
    let text = b"T7,{12.5,-3.9},{1,2},filler {x} {3.25,4}";
    let chars: Vec<i32> = text.iter().take(32).map(|&b| b as i32).collect();
    let mask = vec![1i32; 32];
    let (gf, gb) = ks.char_classify(&chars, &mask).unwrap();
    let (ef, eb) = native::char_classify(&chars, &mask);
    assert_eq!(gf, ef);
    assert_eq!(gb, eb);

    let tags: Vec<i32> = (0..32).map(|i| i / 8).collect();
    let (tf, tb, tc) = ks.tagged_char_stage(&chars, &tags, &mask).unwrap();
    let ksn = KernelSet::native(32);
    let (nf, nb, nc) = ksn.tagged_char_stage(&chars, &tags, &mask).unwrap();
    assert_eq!(tf, nf);
    assert_eq!(tb, nb);
    assert_eq!(tc, nc);
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn coord_parse_xla_matches_native() {
    let eng = engine();
    let ks = xla_set(&eng, 32);
    let wl = ks.window_len();
    let cases = [
        "{12.5,-3.25}",
        "{1,2}",
        "{-116.52,39.93}xx",
        "{bad}",
        "{1.2,}",
        "{1,2",
        "{999999,0.125}",
        "{-0.5,-0.5}",
    ];
    let mut windows = vec![0i32; 32 * wl];
    for i in 0..32 {
        let s = cases[i % cases.len()].as_bytes();
        for (k, &b) in s.iter().take(wl).enumerate() {
            windows[i * wl + k] = b as i32;
        }
    }
    let mask = vec![1i32; 32];
    let (gx, gy, gok) = ks.coord_parse(&windows, &mask).unwrap();
    let (ex, ey, eok) = native::coord_parse(&windows, wl, &mask);
    assert_eq!(gok, eok);
    for i in 0..32 {
        assert!((gx[i] - ex[i]).abs() < 1e-5, "lane {i}: {} vs {}", gx[i], ex[i]);
        assert!((gy[i] - ey[i]).abs() < 1e-5, "lane {i}: {} vs {}", gy[i], ey[i]);
    }
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn executables_are_cached_and_counted() {
    let eng = engine();
    let k1 = eng.kernel(KernelName::SumRegion, 32).unwrap();
    let k2 = eng.kernel(KernelName::SumRegion, 32).unwrap();
    assert!(Rc::ptr_eq(&k1, &k2), "second load must hit the cache");
    let ks = xla_set(&eng, 32);
    let before = eng.total_invocations();
    let vals = vec![1.0f32; 32];
    let mask = vec![1i32; 32];
    ks.sum_region(&vals, &mask, 0.0).unwrap();
    ks.sum_region(&vals, &mask, 0.0).unwrap();
    assert_eq!(eng.total_invocations(), before + 2);
}

#[test]
#[ignore = "needs AOT artifacts + a real PJRT runtime (offline xla stub by default)"]
fn multiple_widths_coexist() {
    let eng = engine();
    for &w in &[32usize, 64, 128] {
        let ks = xla_set(&eng, w);
        let vals = vec![2.0f32; w];
        let mask = vec![1i32; w];
        let (s, c) = ks.sum_region(&vals, &mask, 0.0).unwrap();
        assert_eq!(c as usize, w);
        assert!((s - native::SCALE * 2.0 * w as f32).abs() < 1e-2);
    }
}
