//! Time-windowed sensor statistics — the intro's "stream of measurements
//! grouped by a common time window or event trigger" motivation.
//!
//! Pipeline: windows of samples are enumerated; a calibration stage
//! rescales each sample (uniform work); an aggregator computes per-window
//! mean and peak. Demonstrates BOTH context strategies side by side on
//! the same data and prints the occupancy/time tradeoff, echoing the
//! paper's §5 conclusion that the best representation depends on region
//! size vs SIMD width.
//!
//! Run: `cargo run --example event_windows`

use std::rc::Rc;

use regatta::apps::sum::{SumApp, SumConfig, SumMode, SumShape};
use regatta::coordinator::enumerate::Blob;
use regatta::runtime::kernels::KernelSet;
use regatta::runtime::{ArtifactStore, Engine};
use regatta::util::prng::Prng;

const WIDTH: usize = 128;

fn main() -> anyhow::Result<()> {
    // "sensor" stream: bursty windows — mostly short (event-triggered),
    // occasionally long (steady-state capture)
    let mut rng = Prng::new(99);
    let mut windows = Vec::new();
    let mut id = 0u64;
    let mut total = 0usize;
    while total < 400_000 {
        let len = if rng.chance(0.8) {
            8 + rng.below(48) // short event window << SIMD width
        } else {
            512 + rng.below(1024) // long capture >> SIMD width
        };
        let samples: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        total += len;
        windows.push(Blob::from_vec(id, samples));
        id += 1;
    }
    println!(
        "{} windows, {} samples (bimodal sizes: 80% short, 20% long)",
        windows.len(),
        total
    );

    let (kernels, _engine);
    match ArtifactStore::discover() {
        Ok(store) => {
            let engine = Engine::new(store)?;
            kernels = Rc::new(KernelSet::xla(&engine, WIDTH)?);
            _engine = Some(engine);
        }
        Err(_) => {
            kernels = Rc::new(KernelSet::native(WIDTH));
            _engine = None;
        }
    }

    for (label, mode) in [
        ("signals (sparse context)", SumMode::Enumerated),
        ("tags    (dense context)", SumMode::Tagged),
    ] {
        let app = SumApp::new(
            SumConfig {
                width: WIDTH,
                mode,
                shape: SumShape::Fused,
                threshold: f32::NEG_INFINITY, // keep all samples
                ..Default::default()
            },
            kernels.clone(),
        );
        let report = app.run(&windows)?;
        let node = match mode {
            SumMode::Enumerated => "sum",
            SumMode::Tagged => "tagsum",
        };
        let occ = report.metrics.node(node).unwrap().occupancy();
        println!(
            "{label}: {:>9.3} ms, occupancy {:>5.1}%, {} kernel invocations",
            1e3 * report.elapsed,
            100.0 * occ,
            report.invocations
        );
    }
    println!(
        "\nshort windows favour dense tags (occupancy), long windows favour \
         signals (no per-item tag work) — the paper's central tradeoff."
    );
    Ok(())
}
