//! END-TO-END VALIDATION DRIVER (recorded in EXPERIMENTS.md).
//!
//! Runs the full three-layer system on a real small workload: generates a
//! DIBS-statistics taxi corpus, runs all three context strategies of the
//! paper's Fig. 8 on the AOT-compiled kernels through PJRT, verifies every
//! parsed pair against an independent ground truth, and reports the
//! latency/throughput and occupancy figures the paper reports.
//!
//! Run: `cargo run --release --example taxi_pipeline [lines] [workers]`

use std::rc::Rc;
use std::sync::{Barrier, Mutex};

use regatta::apps::taxi::{
    reference_pairs, sort_pairs, TaxiApp, TaxiConfig, TaxiVariant,
};
use regatta::runtime::kernels::KernelSet;
use regatta::runtime::{ArtifactStore, Engine};
use regatta::simd::{ChunkSource, SimdConfig, SimdMachine};
use regatta::util::stats::{fmt_count, fmt_duration};
use regatta::workload::taxi::{chunk_lines, generate, TaxiGenConfig, TaxiWorkload};

const WIDTH: usize = 128;

fn main() -> anyhow::Result<()> {
    let lines: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(96);
    let workers: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(1);

    println!("== REGATTA end-to-end driver: taxi (DIBS tstcsv->csv) ==\n");
    let w = generate(lines, TaxiGenConfig::default(), 0xE2E);
    let chars: usize = w.lines.iter().map(|l| l.len).sum();
    println!(
        "workload: {} lines, {} chars, {} coordinate pairs (paper stats: 1397 chars, 45 pairs/line)",
        w.lines.len(),
        fmt_count(chars as f64),
        w.total_pairs
    );

    // ground truth, computed independently of kernels and pipeline
    let mut truth = reference_pairs(&w);
    sort_pairs(&mut truth);

    let store = ArtifactStore::discover()
        .map_err(|e| anyhow::anyhow!("{e}\n(run `make artifacts` first)"))?;
    let engine = Engine::new(store)?;
    println!("PJRT platform: {} | width {WIDTH} | {workers} worker(s)\n", engine.platform_name());
    let kernels = Rc::new(KernelSet::xla(&engine, WIDTH)?);

    println!(
        "{:<18} {:>10} {:>12} {:>9} {:>9} {:>8}",
        "variant", "time", "chars/s", "s1_full%", "s2_full%", "pairs"
    );
    for variant in TaxiVariant::all() {
        let (pairs, elapsed, s1, s2) = if workers <= 1 {
            let app = TaxiApp::new(
                TaxiConfig {
                    width: WIDTH,
                    variant,
                    ..Default::default()
                },
                kernels.clone(),
            );
            app.run(&w)?; // warmup (first-touch PJRT costs)
            let r = app.run(&w)?;
            (
                r.pairs,
                r.elapsed,
                r.metrics
                    .node("classify")
                    .map(|n| n.full_fraction())
                    .unwrap_or(0.0),
                r.metrics
                    .node("parse")
                    .map(|n| n.full_fraction())
                    .unwrap_or(0.0),
            )
        } else {
            run_parallel(&w, variant, workers)?
        };

        // verify against ground truth
        let mut got = pairs;
        sort_pairs(&mut got);
        anyhow::ensure!(
            got.len() == truth.len(),
            "{variant:?}: {} vs {} pairs",
            got.len(),
            truth.len()
        );
        for (g, e) in got.iter().zip(&truth) {
            anyhow::ensure!(
                g.tag == e.tag && (g.x - e.x).abs() < 1e-4 && (g.y - e.y).abs() < 1e-4,
                "{variant:?}: pair mismatch"
            );
        }

        println!(
            "{:<18} {:>10} {:>12} {:>9.1} {:>9.1} {:>8}  ✓verified",
            variant.label(),
            fmt_duration(elapsed),
            fmt_count(chars as f64 / elapsed),
            100.0 * s1,
            100.0 * s2,
            got.len()
        );
    }
    println!(
        "\npaper's Fig. 8 shape: hybrid fastest; pure tagging slowest at scale;\n\
         pure-enum stage-1/stage-2 full-ensemble split ≈ 91%/9%."
    );
    Ok(())
}

/// Multi-processor run: the paper's per-SM pipeline instances competing
/// for the input stream, as worker threads claiming line chunks.
fn run_parallel(
    w: &TaxiWorkload,
    variant: TaxiVariant,
    workers: usize,
) -> anyhow::Result<(Vec<regatta::apps::taxi::TaxiPair>, f64, f64, f64)> {
    let chunks: Vec<TaxiWorkload> = chunk_lines(w, (w.lines.len() / (workers * 2)).max(1))
        .into_iter()
        .map(|lines| TaxiWorkload {
            text: w.text.clone(),
            total_pairs: 0,
            lines,
        })
        .collect();
    let source = ChunkSource::new(chunks);
    let machine = SimdMachine::new(SimdConfig {
        width: WIDTH,
        workers,
    });
    let collected = Mutex::new(Vec::new());
    let fulls = Mutex::new((0u64, 0u64, 0u64, 0u64)); // s1 full/total, s2 full/total
    // setup barrier: per-worker engines must compile their kernels before
    // the measured region starts (PJRT clients are thread-confined)
    let barrier = Barrier::new(workers);
    let elapsed_max = Mutex::new(0.0f64);
    machine.run(source, |_wid, src| {
        let engine = Engine::new(ArtifactStore::discover()?)?;
        let kernels = Rc::new(KernelSet::xla(&engine, WIDTH)?);
        let app = TaxiApp::new(
            TaxiConfig {
                width: WIDTH,
                variant,
                ..Default::default()
            },
            kernels,
        );
        barrier.wait();
        let t0 = std::time::Instant::now();
        while let Some(chunk) = src.claim() {
            let r = app.run(chunk)?;
            collected.lock().unwrap().extend(r.pairs);
            let mut f = fulls.lock().unwrap();
            if let Some(n) = r.metrics.node("classify") {
                f.0 += n.full_ensembles;
                f.1 += n.ensembles;
            }
            if let Some(n) = r.metrics.node("parse") {
                f.2 += n.full_ensembles;
                f.3 += n.ensembles;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let mut e = elapsed_max.lock().unwrap();
        if dt > *e {
            *e = dt;
        }
        Ok(())
    })?;
    let elapsed = elapsed_max.into_inner().unwrap();
    let f = fulls.into_inner().unwrap();
    Ok((
        collected.into_inner().unwrap(),
        elapsed,
        f.0 as f64 / f.1.max(1) as f64,
        f.2 as f64 / f.3.max(1) as f64,
    ))
}
