//! Per-kernel PJRT invocation cost at w=128.
use regatta::runtime::kernels::KernelSet;
use regatta::runtime::{ArtifactStore, Engine};
use std::time::Instant;

fn time<F: FnMut()>(n: u32, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..n {
        f();
    }
    t.elapsed().as_secs_f64() / n as f64 * 1e6
}

fn main() -> anyhow::Result<()> {
    let eng = Engine::new(ArtifactStore::discover()?)?;
    let ks = KernelSet::xla(&eng, 128)?;
    let vals = vec![0.5f32; 128];
    let mask = vec![1i32; 128];
    let seg: Vec<i32> = (0..128).map(|i| i / 45).collect();
    let chars = vec![0x7Bi32; 128];
    let windows = vec![0i32; 128 * ks.window_len()];
    // warm all
    ks.sum_region(&vals, &mask, 0.0)?;
    ks.filter_scale(&vals, &mask, 0.0)?;
    ks.masked_sum(&vals, &mask)?;
    ks.segmented_sum(&vals, &seg, &mask)?;
    ks.tagged_sum_region(&vals, &seg, &mask, 0.0)?;
    ks.char_classify(&chars, &mask)?;
    ks.tagged_char_stage(&chars, &seg, &mask)?;
    ks.coord_parse(&windows, &mask)?;
    const N: u32 = 2000;
    let us = time(N, || {
        ks.sum_region(&vals, &mask, 0.0).unwrap();
    });
    println!("sum_region        {us:8.1} us");
    let us = time(N, || {
        ks.filter_scale(&vals, &mask, 0.0).unwrap();
    });
    println!("filter_scale      {us:8.1} us");
    let us = time(N, || {
        ks.masked_sum(&vals, &mask).unwrap();
    });
    println!("masked_sum        {us:8.1} us");
    let us = time(N, || {
        ks.segmented_sum(&vals, &seg, &mask).unwrap();
    });
    println!("segmented_sum     {us:8.1} us");
    let us = time(N, || {
        ks.tagged_sum_region(&vals, &seg, &mask, 0.0).unwrap();
    });
    println!("tagged_sum_region {us:8.1} us");
    let us = time(N, || {
        ks.char_classify(&chars, &mask).unwrap();
    });
    println!("char_classify     {us:8.1} us");
    let us = time(N, || {
        ks.tagged_char_stage(&chars, &seg, &mask).unwrap();
    });
    println!("tagged_char_stage {us:8.1} us");
    let us = time(500, || {
        ks.coord_parse(&windows, &mask).unwrap();
    });
    println!("coord_parse       {us:8.1} us");
    Ok(())
}
