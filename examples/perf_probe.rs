//! Perf probe: where does a PJRT ensemble invocation spend its time?
use regatta::runtime::kernels::KernelSet;
use regatta::runtime::{lit_f32, lit_i32, ArtifactStore, Engine, KernelName};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let eng = Engine::new(ArtifactStore::discover()?)?;
    let ks = KernelSet::xla(&eng, 128)?;
    let vals = vec![0.5f32; 128];
    let mask = vec![1i32; 128];
    ks.sum_region(&vals, &mask, 0.0)?; // warm

    const N: u32 = 5000;
    // (a) full typed call
    let t = Instant::now();
    for _ in 0..N {
        ks.sum_region(&vals, &mask, 0.0)?;
    }
    let full = t.elapsed().as_secs_f64() / N as f64;

    // (b) literal creation only
    let t = Instant::now();
    for _ in 0..N {
        std::hint::black_box((lit_f32(&vals), lit_i32(&mask), lit_f32(&[0.0])));
    }
    let lits = t.elapsed().as_secs_f64() / N as f64;

    // (c) raw execute with pre-built literals
    let k = eng.kernel(KernelName::SumRegion, 128)?;
    let inputs = [lit_f32(&vals), lit_i32(&mask), lit_f32(&[0.0f32])];
    let t = Instant::now();
    for _ in 0..N {
        let r = k.exe_ref().execute::<xla::Literal>(&inputs)?;
        std::hint::black_box(&r);
    }
    let exec_only = t.elapsed().as_secs_f64() / N as f64;

    // (d) execute + fetch result literal + tuple decompose
    let t = Instant::now();
    for _ in 0..N {
        let r = k.exe_ref().execute::<xla::Literal>(&inputs)?;
        let lit = r[0][0].to_literal_sync()?;
        std::hint::black_box(lit.to_tuple()?);
    }
    let exec_fetch = t.elapsed().as_secs_f64() / N as f64;

    println!("full typed call : {:9.2} us", full * 1e6);
    println!("literal creation: {:9.2} us", lits * 1e6);
    println!("execute only    : {:9.2} us", exec_only * 1e6);
    println!("execute + fetch : {:9.2} us", exec_fetch * 1e6);
    println!("typed-call overhead vs execute+fetch: {:6.2} us", (full - exec_fetch) * 1e6);
    Ok(())
}
