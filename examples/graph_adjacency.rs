//! Graph adjacency scan — the intro's "stream of edges in a graph grouped
//! by their source vertex" motivation, on the enumeration/aggregation API.
//!
//! Pipeline: vertices (composites of their out-edges) are enumerated;
//! an edge-filter stage keeps edges whose weight clears a threshold
//! (irregular dataflow); an aggregator computes, per vertex, the surviving
//! out-degree and total weight — a building block of e.g. graph sparsifiers.
//!
//! Run: `cargo run --example graph_adjacency`

use regatta::coordinator::aggregate::{Aggregator, FilterMapLogic};
use regatta::coordinator::enumerate::Composite;
use regatta::coordinator::node::Emitter;
use regatta::coordinator::signal::parent_as;
use regatta::coordinator::topology::PipelineBuilder;
use regatta::util::prng::Prng;

#[derive(Debug, Clone)]
struct Vertex {
    id: u64,
    edges: Vec<(u32, f32)>, // (dst, weight)
}

impl Composite for Vertex {
    fn count(&self) -> usize {
        self.edges.len()
    }
}

fn main() -> anyhow::Result<()> {
    const WIDTH: usize = 64;
    const N_VERTS: usize = 2_000;
    const THRESHOLD: f32 = 0.6;

    // synthetic power-law-ish graph: degree in [0, 256)
    let mut rng = Prng::new(42);
    let mut vertices = Vec::with_capacity(N_VERTS);
    for id in 0..N_VERTS as u64 {
        let deg = (rng.below(16) * rng.below(16)) % 256;
        let edges = (0..deg)
            .map(|_| (rng.below(N_VERTS) as u32, rng.unit_f32()))
            .collect();
        vertices.push(Vertex { id, edges });
    }
    let total_edges: usize = vertices.iter().map(|v| v.edges.len()).sum();

    let mut b = PipelineBuilder::new(WIDTH);
    let src = b.source_with_cap::<Vertex>(N_VERTS);
    let elems = b.enumerate("edges", &src);

    // keep heavy edges only — data-dependent output count per input
    let heavy = b.node(
        "filter",
        &elems,
        FilterMapLogic::new(1, move |idxs: &[u32], parent, out: &mut Emitter<'_, f32>| {
            let v = parent_as::<Vertex>(parent.unwrap()).unwrap();
            for &i in idxs {
                let (_dst, w) = v.edges[i as usize];
                if w > THRESHOLD {
                    out.push(w);
                }
            }
            Ok(())
        }),
    );

    // per-vertex: surviving degree + weight mass
    let stats = b.sink(
        "degree",
        &heavy,
        Aggregator::new(
            (0u32, 0.0f64),
            |acc: &mut (u32, f64), ws: &[f32], _| {
                acc.0 += ws.len() as u32;
                acc.1 += ws.iter().map(|&w| w as f64).sum::<f64>();
                Ok(())
            },
            |acc: &mut (u32, f64), p| {
                let v = parent_as::<Vertex>(p).unwrap();
                Ok(Some((v.id, acc.0, acc.1)))
            },
        ),
    );

    for v in &vertices {
        src.push(v.clone());
    }
    let mut pipe = b.build();
    pipe.run()?;

    let out = stats.borrow();
    let kept: u64 = out.iter().map(|&(_, d, _)| d as u64).sum();
    println!(
        "{} vertices, {} edges -> {} heavy edges ({:.1}%)",
        N_VERTS,
        total_edges,
        kept,
        100.0 * kept as f64 / total_edges as f64
    );
    let mut top: Vec<_> = out.iter().cloned().collect();
    top.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    println!("top-5 vertices by surviving weight:");
    for (id, deg, mass) in top.iter().take(5) {
        println!("  v{id:<6} degree {deg:<4} mass {mass:.3}");
    }
    let m = pipe.metrics();
    print!("\n{}", m.table());
    println!(
        "\nnote the occupancy effect: vertex regions smaller than the SIMD \
         width ({WIDTH}) force partial ensembles in 'filter'."
    );
    Ok(())
}
