//! Quickstart: the paper's running example (Figs 3–5), end to end.
//!
//! ```text
//! Node src : Source<Blob>;
//! Node f   : enumerate Blob -> float from Blob;
//! Node a   : float from Blob -> aggregate double;
//! Node snk : Sink<double>;
//! Edges src -> f -> a -> snk;
//! ```
//!
//! A stream of `Blob` composites is enumerated; node `f` filters each
//! element (`isGood(v)` ⇔ `v > 0`) and scales survivors by 3.14; node `a`
//! aggregates one sum per Blob. Built directly on the public topology
//! API so every moving part of the abstraction is visible.
//!
//! Run: `cargo run --example quickstart` (uses AOT artifacts if present,
//! else the native kernel mirror).

use std::rc::Rc;

use regatta::coordinator::aggregate::{Aggregator, FilterMapLogic};
use regatta::coordinator::enumerate::Blob;
use regatta::coordinator::node::Emitter;
use regatta::coordinator::signal::parent_as;
use regatta::coordinator::topology::PipelineBuilder;
use regatta::runtime::kernels::KernelSet;
use regatta::runtime::{ArtifactStore, Engine};
use regatta::util::prng::Prng;

const WIDTH: usize = 128;

fn main() -> anyhow::Result<()> {
    // kernels: AOT artifacts through PJRT when available
    let (kernels, _engine);
    match ArtifactStore::discover() {
        Ok(store) => {
            let engine = Engine::new(store)?;
            kernels = Rc::new(KernelSet::xla(&engine, WIDTH)?);
            _engine = Some(engine);
            println!("backend: XLA artifacts via PJRT");
        }
        Err(_) => {
            kernels = Rc::new(KernelSet::native(WIDTH));
            _engine = None;
            println!("backend: native mirror (run `make artifacts` for XLA)");
        }
    }

    // ---- topology (paper Fig. 4) ----
    let mut b = PipelineBuilder::new(WIDTH);
    let src = b.source::<Blob>();
    let elems = b.enumerate("enum", &src);

    // node f (paper Fig. 5): filter + scale via the L1 kernel
    let ks = kernels.clone();
    let vals = std::cell::RefCell::new(vec![0.0f32; WIDTH]);
    let mask = std::cell::RefCell::new(Vec::new());
    let filtered = b.node(
        "f",
        &elems,
        FilterMapLogic::new(1, move |idxs: &[u32], parent, out: &mut Emitter<'_, f32>| {
            let blob = parent_as::<Blob>(parent.expect("enumerated")).unwrap();
            let mut vals = vals.borrow_mut();
            let mut mask = mask.borrow_mut();
            for (slot, &i) in vals.iter_mut().zip(idxs) {
                *slot = blob.get(i); // the paper's b->getItem(i)
            }
            for slot in vals.iter_mut().skip(idxs.len()) {
                *slot = 0.0;
            }
            regatta::apps::prefix_mask(&mut mask, idxs.len(), WIDTH);
            let (ov, om) = ks.filter_scale(&vals, &mask, 0.0)?;
            for i in 0..idxs.len() {
                if om[i] != 0 {
                    out.push(ov[i]); // push(3.14 * v) for good v
                }
            }
            Ok(())
        }),
    );

    // node a: begin() zeroes acc, run() accumulates (SIMD reduction),
    // end() pushes the per-Blob sum
    let ks = kernels.clone();
    let avals = std::cell::RefCell::new(vec![0.0f32; WIDTH]);
    let amask = std::cell::RefCell::new(Vec::new());
    let sums = b.sink(
        "a",
        &filtered,
        Aggregator::new(
            0.0f64,
            move |acc: &mut f64, items: &[f32], _| {
                let mut vals = avals.borrow_mut();
                let mut mask = amask.borrow_mut();
                vals[..items.len()].copy_from_slice(items);
                for slot in vals.iter_mut().skip(items.len()) {
                    *slot = 0.0;
                }
                regatta::apps::prefix_mask(&mut mask, items.len(), WIDTH);
                let (partial, _) = ks.masked_sum(&vals, &mask)?;
                *acc += partial as f64;
                Ok(())
            },
            |acc: &mut f64, p| {
                let blob = parent_as::<Blob>(p).unwrap();
                Ok(Some((blob.id, *acc)))
            },
        ),
    );

    // ---- workload: Blobs of varying sizes ----
    let mut rng = Prng::new(7);
    for id in 0..32u64 {
        let n = 50 + rng.below(400);
        let elems: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        src.push(Blob::from_vec(id, elems));
    }

    let mut pipe = b.build();
    pipe.run()?;

    let out = sums.borrow();
    println!("\nper-Blob sums (first 8 of {}):", out.len());
    for (id, s) in out.iter().take(8) {
        println!("  blob {id:>2}: {s:>9.4}");
    }
    let m = pipe.metrics();
    println!("\n{}", m.table());
    println!(
        "pipeline occupancy {:.1}% — partial ensembles appear exactly at Blob boundaries",
        100.0 * m.occupancy()
    );
    Ok(())
}
