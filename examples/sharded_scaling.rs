//! The L3.5 executor in ~40 lines: shard a region stream across worker
//! threads, keep outputs bit-identical and in stream order, and read the
//! per-worker breakdown.
//!
//! Run: `cargo run --release --example sharded_scaling`

use std::rc::Rc;

use regatta::prelude::*;
use regatta::runtime::kernels::KernelSet;
use regatta::workload::regions::gen_blobs;

const WIDTH: usize = 128;

fn main() -> anyhow::Result<()> {
    // 1M-element stream of ~width-sized regions (the interesting regime:
    // every region boundary caps an ensemble).
    let blobs = gen_blobs(1 << 20, RegionSpec::Uniform { max: 2 * WIDTH }, 7);
    println!("stream: {} items in {} regions", 1 << 20, blobs.len());

    let app = SumApp::new(
        SumConfig {
            width: WIDTH,
            ..Default::default()
        },
        Rc::new(KernelSet::native(WIDTH)),
    );

    // Single-threaded reference.
    let single = app.run(&blobs)?;
    println!(
        "1 worker (plain run): {:.3}s, {} sums",
        single.elapsed,
        single.outputs.len()
    );

    // The same pipeline, sharded at region boundaries.
    for workers in [1usize, 2, 4, 8] {
        let report = app.run_sharded(&blobs, workers)?;
        // deterministic merge: same sums, same order, bit for bit
        assert_eq!(report.outputs.len(), single.outputs.len());
        for (a, b) in report.outputs.iter().zip(&single.outputs) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
        println!(
            "{workers} worker(s): {:.3}s  ({:.2}x vs plain run)",
            report.elapsed,
            single.elapsed / report.elapsed
        );
    }

    // Per-worker breakdown comes from the runner directly.
    let factory = SumFactory::new(*app.config(), KernelSpawn::Native);
    let report = ShardedRunner::new(ExecConfig::new(4).with_shards_per_worker(4))
        .run(&factory, &blobs)?;
    println!(
        "\n4 workers, 16 shards — utilization {:.0}%\n{}",
        100.0 * report.utilization(),
        report.worker_table()
    );

    // v2: the same computation streamed — regions generated lazily, at
    // most 1024 in flight, work-stealing workers, same bit-exact sums.
    let source = GenBlobSource::new(1 << 20, RegionSpec::Uniform { max: 2 * WIDTH }, 7);
    let streamed = ShardedRunner::new(ExecConfig::new(4).streaming(1024))
        .run_stream(&factory, source)?;
    assert_eq!(streamed.outputs.len(), single.outputs.len());
    for (a, b) in streamed.outputs.iter().zip(&single.outputs) {
        assert_eq!(a.1.to_bits(), b.1.to_bits());
    }
    println!(
        "4 workers, streaming ingest: {:.3}s, {} shards, {} stolen",
        streamed.elapsed, streamed.shards, streamed.steals
    );
    Ok(())
}
